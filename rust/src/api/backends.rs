//! [`ServingBackend`] implementations for the three request paths:
//! the serial reference system, the sharded per-VR engine, and the
//! multi-FPGA fleet front-end.

use super::plan::{replay_plan, PlanTarget, TenancyPlan};
use super::{ServingBackend, Session, SessionInner, Target, TenantRef};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::server::EngineHandle;
use crate::coordinator::{RegionInfo, ShardedEngine, System};
use crate::fleet::FleetCluster;
use crate::hypervisor::{LifecycleOp, LifecycleOutcome};
use crate::noc::Topology;
use crate::telemetry::TelemetrySnapshot;
use anyhow::{bail, Result};
use std::sync::{Arc, Mutex};

/// Turn an engine-side tenancy snapshot into session targets (engine
/// backends are single-device, so every target is device 0).
fn engine_targets(regions: &[RegionInfo], vi: u16) -> Vec<Target> {
    regions
        .iter()
        .map(|r| Target { device: 0, vi, vr: r.vr, epoch: r.epoch })
        .collect()
}

/// The serial reference [`System`] behind the unified serving surface:
/// one mutex serializes every submission (the serial engine's semantics,
/// lock-shaped), which is exactly the baseline the sharded backend's
/// speedup is measured against. Sessions share the system, so the
/// backend can be driven from multiple client threads; after
/// [`ServingBackend::shutdown`] the system is gone and outstanding
/// sessions error ("engine stopped") exactly like the other backends'.
pub struct SerialBackend {
    sys: super::SharedSystem,
}

impl SerialBackend {
    /// Wrap a built [`System`] (empty or case-study) as a backend.
    pub fn new(sys: System) -> SerialBackend {
        SerialBackend { sys: Arc::new(Mutex::new(Some(sys))) }
    }

    /// Run `f` with exclusive access to the underlying system — the
    /// escape hatch for control-plane work the trait does not cover
    /// (direct lifecycle ops, hypervisor introspection).
    ///
    /// # Panics
    /// Panics if the backend was already shut down (the system is gone).
    pub fn with_system<R>(&self, f: impl FnOnce(&mut System) -> R) -> R {
        f(self
            .sys
            .lock()
            .expect("serial system poisoned")
            .as_mut()
            .expect("serial backend already shut down"))
    }
}

/// [`PlanTarget`] over a directly-owned serial system.
struct SystemTarget<'a> {
    sys: &'a mut System,
}

impl PlanTarget for SystemTarget<'_> {
    fn apply(&mut self, op: &LifecycleOp) -> Result<LifecycleOutcome> {
        self.sys.lifecycle(op)
    }

    fn advance_clock(&mut self, dur_us: f64) -> Result<()> {
        self.sys.core.timing.advance_clock(dur_us);
        Ok(())
    }

    fn adjacent(&self, a: usize, b: usize) -> bool {
        self.sys.hv.topo.vrs_adjacent(a, b)
    }
}

impl ServingBackend for SerialBackend {
    fn label(&self) -> &'static str {
        "serial"
    }

    fn deploy(&self, plan: &TenancyPlan) -> Result<TenantRef> {
        let mut guard = self.sys.lock().expect("serial system poisoned");
        let sys = guard.as_mut().ok_or_else(|| anyhow::anyhow!("engine stopped"))?;
        let (vi, _) = replay_plan(
            &mut SystemTarget { sys },
            plan.migration(),
            plan.name(),
            None,
            plan.attestation(),
        )?;
        Ok(TenantRef::Vi(vi))
    }

    fn session(&self, tenant: TenantRef) -> Result<Session> {
        let TenantRef::Vi(vi) = tenant else {
            bail!("the serial backend addresses tenants by VI id, not fleet tenant id");
        };
        let mut guard = self.sys.lock().expect("serial system poisoned");
        let sys = guard.as_mut().ok_or_else(|| anyhow::anyhow!("engine stopped"))?;
        let regions = crate::coordinator::tenant_regions(&sys.hv, vi);
        if regions.is_empty() {
            bail!("VI {vi} has no programmed regions (unknown VI or nothing deployed)");
        }
        Ok(Session::new(
            tenant,
            engine_targets(&regions, vi),
            SessionInner::Serial(Arc::clone(&self.sys)),
        ))
    }

    fn advance_clock(&self, dur_us: f64) -> Result<()> {
        let mut guard = self.sys.lock().expect("serial system poisoned");
        let sys = guard.as_mut().ok_or_else(|| anyhow::anyhow!("engine stopped"))?;
        sys.core.timing.advance_clock(dur_us);
        Ok(())
    }

    fn telemetry_snapshot(&self) -> Result<TelemetrySnapshot> {
        let guard = self.sys.lock().expect("serial system poisoned");
        let sys = guard.as_ref().ok_or_else(|| anyhow::anyhow!("engine stopped"))?;
        Ok(sys.telemetry.snapshot())
    }

    fn shutdown(self) -> Metrics {
        // Take the system out: outstanding sessions now error ("engine
        // stopped") exactly like calls onto a stopped engine or fleet.
        self.sys
            .lock()
            .expect("serial system poisoned")
            .take()
            .map(|sys| sys.metrics)
            .unwrap_or_default()
    }
}

/// [`PlanTarget`] over an engine's message stream: ops apply at their
/// arrival position, adjacency reads the engine's static topology.
pub(crate) struct HandleTarget<'a> {
    pub(crate) handle: &'a EngineHandle,
    pub(crate) topo: &'a Topology,
}

impl PlanTarget for HandleTarget<'_> {
    fn apply(&mut self, op: &LifecycleOp) -> Result<LifecycleOutcome> {
        self.handle.lifecycle(op.clone())
    }

    fn advance_clock(&mut self, dur_us: f64) -> Result<()> {
        self.handle.advance_clock(dur_us)
    }

    fn adjacent(&self, a: usize, b: usize) -> bool {
        self.topo.vrs_adjacent(a, b)
    }
}

impl ServingBackend for ShardedEngine {
    fn label(&self) -> &'static str {
        "sharded"
    }

    fn deploy(&self, plan: &TenancyPlan) -> Result<TenantRef> {
        let handle = self.handle();
        let mut target = HandleTarget { handle: &handle, topo: self.topology() };
        let (vi, _) =
            replay_plan(&mut target, plan.migration(), plan.name(), None, plan.attestation())?;
        Ok(TenantRef::Vi(vi))
    }

    fn session(&self, tenant: TenantRef) -> Result<Session> {
        let TenantRef::Vi(vi) = tenant else {
            bail!("the sharded backend addresses tenants by VI id, not fleet tenant id");
        };
        let regions = self.handle().describe(vi)?;
        if regions.is_empty() {
            bail!("VI {vi} has no programmed regions (unknown VI or nothing deployed)");
        }
        Ok(Session::new(
            tenant,
            engine_targets(&regions, vi),
            SessionInner::Engine(self.handle()),
        ))
    }

    fn advance_clock(&self, dur_us: f64) -> Result<()> {
        self.handle().advance_clock(dur_us)
    }

    fn telemetry_snapshot(&self) -> Result<TelemetrySnapshot> {
        self.handle().telemetry_snapshot()
    }

    fn shutdown(self) -> Metrics {
        ShardedEngine::stop(self)
    }
}

impl ServingBackend for FleetCluster {
    fn label(&self) -> &'static str {
        "fleet"
    }

    fn deploy(&self, plan: &TenancyPlan) -> Result<TenantRef> {
        Ok(TenantRef::Tenant(self.deploy_tenancy(plan)?))
    }

    fn session(&self, tenant: TenantRef) -> Result<Session> {
        let TenantRef::Tenant(id) = tenant else {
            bail!("the fleet backend addresses tenants by fleet-wide tenant id, not VI");
        };
        let replicas = self.replicas(id);
        if replicas.is_empty() {
            bail!("tenant {id} has no live replica (unknown, retired, or displaced)");
        }
        let targets = replicas
            .iter()
            .map(|r| Target { device: r.device, vi: r.vi, vr: r.vr, epoch: r.epoch })
            .collect();
        Ok(Session::new(tenant, targets, SessionInner::Fleet(self.device_handles())))
    }

    fn advance_clock(&self, dur_us: f64) -> Result<()> {
        self.advance_clocks(dur_us)
    }

    fn telemetry_snapshot(&self) -> Result<TelemetrySnapshot> {
        // Merge the live devices' snapshots. A failed device's engine is
        // gone from the fleet — its final telemetry was captured as an
        // `Incident` by `fail_device`, not lost — so dead devices are
        // skipped here rather than erroring the whole collection.
        let mut merged = TelemetrySnapshot::default();
        for snap in self.device_telemetry()? {
            merged.merge(&snap);
        }
        Ok(merged)
    }

    fn shutdown(self) -> Metrics {
        self.stop().unwrap_or_else(|_| {
            // Another clone already stopped the scheduler; its metrics
            // went with it, so this clone has nothing further to add.
            Metrics::default()
        })
    }
}

// Compile-time guarantee that the trait stays object-safe (callers hold
// heterogeneous backends behind `&dyn ServingBackend`).
#[allow(dead_code)]
fn _assert_backend_object_safe(backend: &dyn ServingBackend) -> &'static str {
    backend.label()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::TenancyBuilder;

    #[test]
    fn serial_backend_sessions_serve_and_pin_epochs() {
        let backend = SerialBackend::new(System::empty("artifacts").unwrap());
        let plan = TenancyBuilder::new("t").region("fir").plan().unwrap();
        let tenant = backend.deploy(&plan).unwrap();
        backend.advance_clock(20_000.0).unwrap();
        let session = backend.session(tenant).unwrap();
        assert_eq!(session.targets().len(), 1);
        let resp = session.submit(0, vec![1u8; 64]).unwrap();
        assert_eq!(resp.path, vec!["fir".to_string()]);
        assert_eq!(resp.epoch, session.targets()[0].epoch, "response carries the pinned epoch");
        assert!(session.submit(1, vec![0u8; 8]).is_err(), "unknown region index");
        let metrics = backend.shutdown();
        assert_eq!(metrics.requests, 1);
    }

    #[test]
    fn stale_sessions_are_refused_after_the_region_moves() {
        let backend = SerialBackend::new(System::empty("artifacts").unwrap());
        let plan = TenancyBuilder::new("t").region("fir").plan().unwrap();
        let TenantRef::Vi(vi) = backend.deploy(&plan).unwrap() else { unreachable!() };
        backend.advance_clock(20_000.0).unwrap();
        let session = backend.session(TenantRef::Vi(vi)).unwrap();
        let vr = session.targets()[0].vr;
        assert!(session.submit(0, vec![2u8; 32]).is_ok());
        // The tenant reprograms its region: the epoch moves, the old
        // session goes stale, a fresh session serves again.
        backend.with_system(|sys| {
            sys.lifecycle(&LifecycleOp::Program {
                vi,
                vr,
                design: "fft".into(),
                dest: None,
            })
            .unwrap();
            sys.core.timing.advance_clock(20_000.0);
        });
        let err = session.submit(0, vec![2u8; 32]).unwrap_err();
        assert!(err.to_string().contains("stale session"), "got: {err}");
        let fresh = backend.session(TenantRef::Vi(vi)).unwrap();
        assert_eq!(fresh.submit(0, vec![2u8; 64]).unwrap().path, vec!["fft".to_string()]);
        let metrics = backend.shutdown();
        assert_eq!(metrics.rejected, 1, "the stale submission counts as a rejection");
        assert_eq!(metrics.requests, 2);
    }

    #[test]
    fn failed_deploy_rolls_back_to_a_clean_pool() {
        let backend = SerialBackend::new(System::empty("artifacts").unwrap());
        // 7 regions on a 6-VR floorplan: allocation fails partway.
        let mut builder = TenancyBuilder::new("greedy");
        for _ in 0..7 {
            builder = builder.region("fir");
        }
        let plan = builder.plan().unwrap();
        assert!(backend.deploy(&plan).is_err());
        backend.with_system(|sys| {
            assert_eq!(sys.hv.free_vrs(), 6, "rollback must return every region");
            assert!(sys.hv.vis.is_empty(), "rollback must destroy the created VI");
        });
    }

    #[test]
    fn sharded_backend_batches_and_pipelines() {
        use crate::api::BatchItem;
        let engine = ShardedEngine::start(|| System::empty("artifacts")).unwrap();
        let plan = TenancyBuilder::new("pair")
            .region("fpu")
            .region("aes")
            .stream(0, 1)
            .plan()
            .unwrap();
        let tenant = engine.deploy(&plan).unwrap();
        engine.advance_clock(40_000.0).unwrap();
        let session = engine.session(tenant).unwrap();
        assert_eq!(session.targets().len(), 2);
        // Async pipelining: both pendings complete with the right paths.
        let mut a = session.submit_async(0, vec![5u8; 64]).unwrap();
        let b = session.submit_async(1, vec![6u8; 32]).unwrap();
        while !a.poll() {
            std::thread::yield_now();
        }
        let ra = a.wait().unwrap();
        assert_eq!(ra.path, vec!["fpu".to_string(), "aes".to_string()]);
        assert_eq!(b.wait().unwrap().path, vec!["aes".to_string()]);
        // Batch: one message, results in slice order.
        let batch: Vec<BatchItem> = (0..6).map(|i| BatchItem::new(i % 2, vec![7u8; 48])).collect();
        let results = session.submit_batch(&batch).unwrap();
        assert_eq!(results.len(), 6);
        for (i, r) in results.iter().enumerate() {
            let resp = r.as_ref().unwrap();
            let expect: &[&str] = if i % 2 == 0 { &["fpu", "aes"] } else { &["aes"] };
            assert_eq!(resp.path, expect, "batch item {i}");
        }
        let metrics = engine.shutdown();
        assert_eq!(metrics.requests, 8);
        assert_eq!(metrics.batches, 1, "one arrival slice, one batch");
    }
}
