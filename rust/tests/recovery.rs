//! Event-sourced control plane: crash-point recovery, tail repair, and
//! active/standby failover — the PR 7 robustness gates.
//!
//! The journal is the durable truth and the scheduler is a cache of its
//! replay, so the tests here all have the same shape: run a live
//! controller, then prove the journal alone reconstructs it.
//!
//! - **Crash at every boundary**: the controller can die between any two
//!   appends — including mid-migration, after the route flip but before
//!   the source teardown — and recovery from that prefix must be
//!   byte-identical to what the live controller held at that point.
//! - **Tail repair**: a torn last frame (crash mid-write) or a corrupt
//!   entry (bit rot) truncates to the clean prefix; `Journal::open`
//!   refuses a damaged store outright until recovery repairs it.
//! - **Attested plans**: a tampered `PlanSealed` tag is refused at
//!   replay even when the frame checksums are re-computed to match.
//! - **Fencing**: after failover the stale controller's next mutation is
//!   refused before it touches any state, and the promoted standby holds
//!   byte-identical state and keeps serving.
//! - **Serving equivalence**: a recovered fleet answers requests with
//!   the same modeled timings, outputs, and epochs as the live run.

use fpga_mt::control::{
    compacted_log, control_trace, decode_log, drive_control_trace, recover_scheduler, ControlOp,
    CrashPlan, HaFleet, Journal, LogStore, MemLog,
};
use fpga_mt::coordinator::System;
use fpga_mt::fleet::{FleetConfig, FleetScheduler, PlacePolicy, RouteUnavailable, TenantId};
use fpga_mt::hypervisor::{LifecycleOp, LifecycleOutcome};

/// Boot a journaled fleet (digest trace on) and drive a seeded
/// control-only churn trace through it.
fn journaled_fleet(devices: usize, events: usize, seed: u64) -> (FleetScheduler, MemLog) {
    let mut sched = FleetScheduler::start(FleetConfig {
        policy: PlacePolicy::Spread,
        ..FleetConfig::new(devices)
    })
    .expect("fleet boots");
    let log = MemLog::new();
    sched.attach_journal(Box::new(log.clone()), true).expect("journal attaches");
    drive_control_trace(&mut sched, &control_trace(devices, events, seed));
    (sched, log)
}

/// The device a tenant's replica was last bound to, per the journal.
fn device_of(log: &MemLog, tenant: TenantId) -> usize {
    let (entries, _, _) = decode_log(&log.snapshot());
    entries
        .iter()
        .rev()
        .find_map(|e| match &e.op {
            ControlOp::BindReplica { tenant: t, device, .. } if *t == tenant => {
                Some(*device as usize)
            }
            _ => None,
        })
        .expect("tenant has a journaled binding")
}

#[test]
fn crash_at_every_boundary_recovers_byte_identical_state() {
    let mut sched = FleetScheduler::start(FleetConfig {
        policy: PlacePolicy::Spread,
        ..FleetConfig::new(2)
    })
    .expect("fleet boots");
    let log = MemLog::new();
    sched.attach_journal(Box::new(log.clone()), true).expect("journal attaches");

    // An explicit migration guarantees the journal contains the
    // mid-migration crash window: the route flip (`SetRoutes`) lands
    // entries before the source teardown and the `MigrateDone` record,
    // so the sweep below kills the controller inside the migration.
    let mover = sched.admit_tenant("mover", "aes").expect("admits");
    sched.advance_clocks(10_000.0).expect("clocks advance");
    let from = device_of(&log, mover);
    sched.migrate_tenant(mover, from, 1 - from).expect("live migration");

    // Seeded control churn for breadth: admissions, replica growth,
    // retirement, decommission, and device failure (whose recovery path
    // itself replays the dead device's tenancy from this journal).
    let stats = drive_control_trace(&mut sched, &control_trace(2, 14, 0xF1EE7));
    assert!(stats.admitted > 0, "churn trace admitted no tenants");

    let (entries, _, damage) = decode_log(&log.snapshot());
    assert!(damage.is_none(), "live journal must be clean: {damage:?}");
    assert!(
        entries.iter().any(|e| matches!(e.op, ControlOp::MigrateDone { .. })),
        "journal records no completed migration"
    );
    assert!(
        entries.iter().any(|e| matches!(e.op, ControlOp::PlanSealed { .. })),
        "journal records no attested plan"
    );

    let plan = CrashPlan::capture(&sched).expect("crash plan captures");
    assert!(plan.len() > 20, "crash surface too small: {} entries", plan.len());
    let checked = plan.assert_all_boundaries().expect("every boundary recovers");
    assert_eq!(checked, plan.len());
    let _ = sched.stop();
}

#[test]
fn recovered_fleet_serves_identically_to_the_live_run() {
    let mut live = FleetScheduler::start(FleetConfig {
        policy: PlacePolicy::Spread,
        ..FleetConfig::new(2)
    })
    .expect("fleet boots");
    live.attach_journal(Box::new(MemLog::new()), true).expect("journal attaches");
    let a = live.admit_tenant("a", "fir").expect("admits a");
    let b = live.admit_tenant("b", "huffman").expect("admits b");
    live.advance_clocks(20_000.0).expect("deploy windows elapse");
    let from = live
        .migrate_tenant(a, 0, 1)
        .map(|_| ())
        .or_else(|_| live.migrate_tenant(a, 1, 0).map(|_| ()));
    from.expect("one migration direction succeeds");

    let plan = CrashPlan::capture(&live).expect("crash plan captures");
    let (recovered, report) = plan.recover_at(plan.len() - 1).expect("final boundary recovers");
    assert!(report.truncated.is_none());
    assert_eq!(recovered.control_digest(), live.control_digest());

    // The recovered fleet must answer like the live one: same devices,
    // same epochs, same outputs, same *modeled* timing parts (IO trip,
    // NoC cycles, ingress) — compute wall time is real time and is the
    // only field allowed to differ.
    let (lh, rh) = (live.handle(), recovered.handle());
    for i in 0..4u8 {
        for &t in &[a, b] {
            let x = lh.submit(t, vec![i + 1; 96]).expect("live serve");
            let y = rh.submit(t, vec![i + 1; 96]).expect("recovered serve");
            assert_eq!(x.device, y.device, "request routed to a different device");
            assert_eq!(x.epoch, y.epoch, "replica epoch diverged");
            assert_eq!(x.ingress_us.to_bits(), y.ingress_us.to_bits());
            assert_eq!(x.response.outputs, y.response.outputs, "payload outputs diverged");
            assert_eq!(x.response.path, y.response.path, "accelerator path diverged");
            assert_eq!(x.response.epoch, y.response.epoch);
            assert_eq!(x.response.timing.io_us.to_bits(), y.response.timing.io_us.to_bits());
            assert_eq!(x.response.timing.noc_cycles, y.response.timing.noc_cycles);
        }
    }
    let _ = live.stop();
    let _ = recovered.stop();
}

#[test]
fn torn_tail_is_truncated_and_recovery_matches_the_clean_prefix() {
    let (sched, log) = journaled_fleet(2, 10, 0xBADC0FFE);
    let full = log.snapshot();
    let clean_entries = decode_log(&full).0.len();
    let digest = sched.control_digest();

    // A crash mid-append leaves a torn frame: here, half a length prefix.
    let mut torn = full.clone();
    torn.extend_from_slice(&[0x55, 0x01]);
    let (rec, report) =
        recover_scheduler(Box::new(MemLog::with_bytes(torn, 0))).expect("torn tail recovers");
    let damage = report.truncated.expect("tail damage reported");
    assert_eq!(damage.offset, full.len(), "damage offset must be the clean prefix length");
    assert!(damage.reason.contains("torn"), "unexpected reason: {}", damage.reason);
    assert_eq!(report.entries, clean_entries);
    assert_eq!(rec.control_digest(), digest, "clean-prefix recovery diverged");
    let _ = rec.stop();
    let _ = sched.stop();
}

#[test]
fn corrupt_tail_entry_is_truncated_and_direct_reopen_refuses() {
    let (sched, log) = journaled_fleet(2, 10, 0xDEAD5EED);
    let full = log.snapshot();
    let (entries, _, _) = decode_log(&full);
    let n = entries.len();

    // Flip one byte inside the last frame's body: the checksum catches
    // it and the whole entry is amputated.
    let last_len = entries.last().expect("non-empty journal").encode_frame().len();
    let mut corrupt = full.clone();
    let body_off = full.len() - last_len + 4;
    corrupt[body_off] ^= 0xFF;

    // Journal::open refuses a damaged store outright — only recovery,
    // which repairs the tail, may open it.
    let err = match Journal::open(Box::new(MemLog::with_bytes(corrupt.clone(), 0))) {
        Ok(_) => panic!("damaged journal must not open directly"),
        Err(e) => e,
    };
    assert!(err.to_string().contains("recover first"), "unexpected error: {err}");

    let (mut rec, report) =
        recover_scheduler(Box::new(MemLog::with_bytes(corrupt, 0))).expect("corruption recovers");
    let damage = report.truncated.expect("corruption reported");
    assert!(damage.reason.contains("checksum"), "unexpected reason: {}", damage.reason);
    assert_eq!(report.entries, n - 1, "exactly the corrupt entry is lost");
    // The store was repaired in place: the recovered controller appends
    // where the clean prefix ends.
    rec.advance_clocks(100.0).expect("recovered controller keeps journaling");
    let _ = rec.stop();
    let _ = sched.stop();
}

#[test]
fn tampered_plan_attestation_is_refused_on_replay() {
    let mut sched = FleetScheduler::start(FleetConfig {
        policy: PlacePolicy::Spread,
        ..FleetConfig::new(2)
    })
    .expect("fleet boots");
    let log = MemLog::new();
    sched.attach_journal(Box::new(log.clone()), false).expect("journal attaches");
    let mover = sched.admit_tenant("mover", "fft").expect("admits");
    sched.advance_clocks(10_000.0).expect("clocks advance");
    let from = device_of(&log, mover);
    sched.migrate_tenant(mover, from, 1 - from).expect("migration seals a plan");

    // Re-encode the journal with one attestation tag bit flipped. Every
    // frame checksum is recomputed over the tampered body, so nothing
    // short of the replay-time attestation check can catch it.
    let (entries, _, _) = decode_log(&log.snapshot());
    let mut bytes = Vec::new();
    let mut tampered = false;
    for mut e in entries {
        if let ControlOp::PlanSealed { tag, .. } = &mut e.op {
            tag[0] ^= 1;
            tampered = true;
        }
        bytes.extend_from_slice(&e.encode_frame());
    }
    assert!(tampered, "journal holds no sealed plan to tamper with");
    let err = match recover_scheduler(Box::new(MemLog::with_bytes(bytes, 0))) {
        Ok(_) => panic!("tampered attestation must abort recovery"),
        Err(e) => e,
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("replaying journal entry seq"), "unexpected error: {msg}");
    let _ = sched.stop();
}

#[test]
fn failover_fences_the_stale_controller_and_preserves_state() {
    let mut ha = HaFleet::start(FleetConfig::new(2), false).expect("HA pair boots");
    let t = ha.active().admit_tenant("ha-tenant", "canny").expect("admits");
    ha.active().advance_clocks(20_000.0).expect("clocks advance");
    assert!(ha.standby().catch_up() > 0, "standby saw no entries");

    let (mut stale, report) = ha.fail_controller().expect("standby takes over");
    assert_eq!(ha.failovers(), 1);
    assert_eq!(report.fence, 1, "takeover writes under the raised fence");
    assert!(report.truncated.is_none());

    // The stale controller's next mutation is refused at the fence,
    // before any state is touched…
    let err = stale.admit_tenant("late", "fir").expect_err("stale controller must be fenced");
    assert!(err.to_string().contains("fenced"), "unexpected error: {err}");
    // …so its state still equals what the standby rebuilt from the log.
    assert_eq!(ha.active().control_digest(), stale.control_digest());

    // The promoted standby keeps admitting and serving under the new
    // fence.
    let t2 = ha.active().admit_tenant("post-failover", "fir").expect("new active admits");
    ha.active().advance_clocks(20_000.0).expect("clocks advance");
    let handle = ha.active().handle();
    assert!(handle.submit(t, vec![3u8; 64]).is_ok(), "pre-failover tenant still serves");
    assert!(handle.submit(t2, vec![4u8; 64]).is_ok(), "post-failover tenant serves");
    let _ = stale.stop();
    let _ = ha.stop();
}

#[test]
fn standby_tails_the_journal_incrementally() {
    let mut ha = HaFleet::start(FleetConfig::new(1), false).expect("HA pair boots");
    // Catch-up right after boot sees exactly the Boot header.
    assert_eq!(ha.standby().catch_up(), 1);
    ha.active().admit_tenant("one", "fir").expect("admits");
    let first = ha.standby().catch_up();
    assert!(first > 0, "standby missed the admission's entries");
    assert_eq!(ha.standby().catch_up(), 0, "no new entries, no new count");
    ha.active().advance_clocks(1_000.0).expect("clocks advance");
    assert_eq!(ha.standby().catch_up(), 1, "one clock entry on the single device");
    assert_eq!(ha.standby().entries().len(), first + 2);
    let _ = ha.stop();
}

#[test]
fn retired_tenant_fails_fast_with_route_unavailable() {
    let mut sched = FleetScheduler::start(FleetConfig::new(1)).expect("fleet boots");
    let t = sched.admit_tenant("ephemeral", "fir").expect("admits");
    sched.advance_clocks(20_000.0).expect("clocks advance");
    let handle = sched.handle();
    assert!(handle.submit(t, vec![1u8; 64]).is_ok(), "serves while routed");
    sched.retire_tenant(t).expect("retires");
    // The front-end fails fast with the terminal typed error — no
    // spinning on a tenant whose routes are permanently gone.
    let err = handle.submit(t, vec![2u8; 64]).expect_err("retired tenant must not serve");
    let route = err
        .downcast_ref::<RouteUnavailable>()
        .expect("terminal routing error is typed RouteUnavailable");
    assert_eq!(route.tenant, t);
    assert_eq!(route.attempts, 0, "scrubbed routes must not be retried");
    let _ = sched.stop();
}

#[test]
fn compacted_journal_recovers_equivalent_serving_state() {
    // Long history, small state: three admissions, two retirements, one
    // growth, one migration. Compaction must rebuild the same *serving*
    // state from O(state) entries instead of O(history).
    let mut sched = FleetScheduler::start(FleetConfig {
        policy: PlacePolicy::Spread,
        ..FleetConfig::new(2)
    })
    .expect("fleet boots");
    let log = MemLog::new();
    sched.attach_journal(Box::new(log.clone()), false).expect("journal attaches");
    let a = sched.admit_tenant("a", "fir").expect("admits a");
    let b = sched.admit_tenant("b", "aes").expect("admits b");
    let c = sched.admit_tenant("c", "fft").expect("admits c");
    sched.advance_clocks(20_000.0).expect("clocks advance");
    sched.grow_tenant(b).expect("grows b");
    sched.retire_tenant(a).expect("retires a");
    sched.retire_tenant(c).expect("retires c");
    let from = device_of(&log, b);
    // b has replicas on both devices after the grow; migration may be
    // refused (target already holds one) — either way the history is
    // long and the live state is small.
    let _ = sched.migrate_tenant(b, from, 1 - from);

    let full_entries = decode_log(&log.snapshot()).0.len();
    let compact = compacted_log(&sched, log.fence()).expect("compaction synthesizes");
    let compact_entries = decode_log(&compact.snapshot()).0.len();
    assert!(
        compact_entries < full_entries,
        "compaction must shrink the journal: {compact_entries} >= {full_entries}"
    );

    let (recovered, report) =
        recover_scheduler(Box::new(compact)).expect("compacted journal recovers");
    assert!(report.truncated.is_none());
    // VI numbering and route versions may differ; everything a client
    // can observe must not.
    assert_eq!(recovered.serving_digest(), sched.serving_digest());
    // And it actually serves: the surviving tenant answers requests.
    let handle = recovered.handle();
    assert!(handle.submit(b, vec![5u8; 64]).is_ok(), "recovered fleet serves");
    let _ = recovered.stop();
    let _ = sched.stop();
}

#[test]
fn system_journal_replays_a_single_device_tenancy() {
    let log = MemLog::new();
    let mut sys = System::empty("artifacts").expect("system boots");
    sys.attach_journal(Box::new(log.clone())).expect("journal attaches");

    let LifecycleOutcome::Vi(vi) = sys
        .lifecycle(&LifecycleOp::CreateVi { name: "t0".into() })
        .expect("create vi")
    else {
        panic!("CreateVi returns a Vi outcome");
    };
    let LifecycleOutcome::Vr(vr) =
        sys.lifecycle(&LifecycleOp::Allocate { vi }).expect("allocate")
    else {
        panic!("Allocate returns a Vr outcome");
    };
    sys.lifecycle(&LifecycleOp::Program { vi, vr, design: "fpu".into(), dest: None })
        .expect("program");
    let before = decode_log(&log.snapshot()).0.len();
    // A refused op must never enter the durable history (apply-then-
    // journal): programming a VR the VI does not hold is denied.
    let foreign = (vr + 1) % sys.hv.vrs.len();
    assert!(sys
        .lifecycle(&LifecycleOp::Program { vi, vr: foreign, design: "aes".into(), dest: None })
        .is_err());
    let (entries, _, damage) = decode_log(&log.snapshot());
    assert!(damage.is_none());
    assert_eq!(entries.len(), before, "a refused op was journaled");

    // Replay onto a fresh empty system rebuilds the exact tenancy.
    let mut rebuilt = System::empty("artifacts").expect("fresh system boots");
    let applied = rebuilt.replay_journal(&entries).expect("journal replays");
    assert_eq!(applied, entries.len());
    let live: Vec<_> = sys.hv.vrs.iter().map(|r| (r.status.clone(), r.epoch)).collect();
    let replayed: Vec<_> = rebuilt.hv.vrs.iter().map(|r| (r.status.clone(), r.epoch)).collect();
    assert_eq!(live, replayed, "replayed tenancy diverged");
    assert_eq!(sys.hv.vis[&vi].vrs, rebuilt.hv.vis[&vi].vrs);
}
