//! Isolation gate — the red-team counterpart of the backend conformance
//! suite.
//!
//! One seeded hostile trace (six attack classes layered on cooperative
//! churn, see `coordinator::redteam`) replays through the serial
//! backend, the sharded engine, and a single-device fleet. The gate:
//!
//! - the canonical replay log is **byte-identical** on all three
//!   backends — every attack is refused at the same position with the
//!   same error string;
//! - every attack class lands in the same counter everywhere: foreign
//!   probes and stale tickets in `rejected`, hostile lifecycle ops in
//!   `denied_ops`, flood tails in `backpressured`;
//! - **zero foreign bytes** are delivered across the tenancy boundary;
//! - the cross-tenant side-channel proxy stays under its gated bound
//!   for every co-located tenant pairing of the case-study deployment;
//! - unattested, tampered, and foreign-key tenancy plans are refused by
//!   `deploy` on every backend, leaking no resources.

use fpga_mt::api::{
    AttestationKey, SerialBackend, ServingBackend, TenancyBuilder, TenancyPlan,
};
use fpga_mt::coordinator::metrics::Metrics;
use fpga_mt::coordinator::redteam::{
    self, AttackClass, AttackSurface, RedteamConfig, RedteamEvent, RedteamReplay,
};
use fpga_mt::coordinator::{ShardedEngine, System};
use fpga_mt::estimate::{leakage_between, TenantActivity, LEAKAGE_BOUND};
use fpga_mt::fleet::{FleetCluster, FleetConfig};
use fpga_mt::noc::Topology;

struct GateRun {
    label: &'static str,
    replay: RedteamReplay,
    metrics: Metrics,
}

/// Replay the hostile trace through one backend (every backend is both
/// a `ServingBackend` and an `AttackSurface`), then shut it down for
/// its merged metrics.
fn run_surface<B: ServingBackend + AttackSurface>(backend: B, trace: &[RedteamEvent]) -> GateRun {
    let label = backend.surface_label();
    let replay = redteam::replay(&backend, trace);
    let metrics = backend.shutdown();
    GateRun { label, replay, metrics }
}

fn assert_gates(run: &GateRun) {
    let label = run.label;
    assert_eq!(
        run.replay.coop_op_failures, 0,
        "{label}: every cooperative op in the trace must apply"
    );
    assert_eq!(
        run.replay.foreign_bytes, 0,
        "{label}: no payload byte may cross the tenancy boundary"
    );
    assert!(run.replay.all_classes_attempted(), "{label}: trace must cover every attack class");
    for class in AttackClass::ALL {
        let tally = run.replay.tally(class);
        if class == AttackClass::IngressFlood {
            assert!(
                tally.refused > 0,
                "{label}: flood tails must be backpressured ({} attempts)",
                tally.attempts
            );
            assert!(
                tally.attempts > tally.refused,
                "{label}: flood heads must queue (bounded backlog, not a closed door)"
            );
        } else {
            assert_eq!(
                tally.refused,
                tally.attempts,
                "{label}: every {} attempt must be refused",
                class.label()
            );
        }
    }
    // Each enforcement point must actually fire into its own counter.
    assert!(run.metrics.rejected > 0, "{label}: access/epoch refusals must count");
    assert!(run.metrics.backpressured > 0, "{label}: flood backpressure must count");
    assert!(run.metrics.denied_ops > 0, "{label}: hostile lifecycle ops must count");
}

fn assert_runs_identical(a: &GateRun, b: &GateRun) {
    let pair = format!("{} vs {}", a.label, b.label);
    assert_eq!(a.replay.log.len(), b.replay.log.len(), "{pair}: trace length");
    for (i, (x, y)) in a.replay.log.iter().zip(&b.replay.log).enumerate() {
        assert_eq!(x, y, "{pair}: replay log diverges at event {i}");
    }
    assert_eq!(a.replay.tallies, b.replay.tallies, "{pair}: per-class tallies");
    assert_eq!(a.metrics.requests, b.metrics.requests, "{pair}: requests");
    assert_eq!(a.metrics.rejected, b.metrics.rejected, "{pair}: rejected");
    assert_eq!(a.metrics.backpressured, b.metrics.backpressured, "{pair}: backpressured");
    assert_eq!(a.metrics.denied_ops, b.metrics.denied_ops, "{pair}: denied_ops");
    assert_eq!(a.metrics.bytes_in, b.metrics.bytes_in, "{pair}: bytes_in");
    assert_eq!(a.metrics.bytes_out, b.metrics.bytes_out, "{pair}: bytes_out");
}

#[test]
fn hostile_trace_is_refused_identically_on_all_three_backends() {
    let trace = redteam::generate(&RedteamConfig::default());
    let serial = run_surface(SerialBackend::new(System::empty("artifacts").unwrap()), &trace);
    let sharded = run_surface(ShardedEngine::start(|| System::empty("artifacts")).unwrap(), &trace);
    let fleet = run_surface(FleetCluster::start(FleetConfig::new(1)).unwrap(), &trace);
    for run in [&serial, &sharded, &fleet] {
        assert_gates(run);
    }
    assert_runs_identical(&serial, &sharded);
    assert_runs_identical(&serial, &fleet);
    assert_runs_identical(&sharded, &fleet);
}

#[test]
fn hostile_traces_are_seed_stable_on_one_backend() {
    // Same seed, two independent replays on fresh serial systems: the
    // canonical log is a pure function of (seed, backend semantics).
    let cfg = RedteamConfig { seed: 0x5EC_0ED, events: 150, attack_rate: 0.4 };
    let trace = redteam::generate(&cfg);
    let a = run_surface(SerialBackend::new(System::empty("artifacts").unwrap()), &trace);
    let b = run_surface(SerialBackend::new(System::empty("artifacts").unwrap()), &trace);
    assert_eq!(a.replay.log, b.replay.log);
    assert_eq!(a.metrics.requests, b.metrics.requests);
    assert_eq!(a.metrics.rejected, b.metrics.rejected);
}

#[test]
fn leakage_stays_bounded_for_every_co_located_pairing() {
    // Case-study deployment: 3 routers on one physical column, 6 VRs,
    // three two-region tenants — the densest co-location the floorplan
    // offers. Every (attacker, victim) pairing must stay under the
    // gated bound at full victim duty.
    let topo = Topology::single_column(3);
    let holdings: [[usize; 2]; 3] = [[0, 1], [2, 3], [4, 5]];
    let mut worst = 0.0f64;
    for (ai, attacker) in holdings.iter().enumerate() {
        for (vi, victim) in holdings.iter().enumerate() {
            if ai == vi {
                continue;
            }
            let report = leakage_between(&topo, attacker, &TenantActivity::new(victim, 1.0));
            assert!(
                report.within_bound(),
                "attacker {attacker:?} vs victim {victim:?}: score {:.4} >= {LEAKAGE_BOUND}",
                report.score
            );
            assert!(report.score > 0.0, "shared substrate: the proxy must not report zero");
            worst = worst.max(report.score);
        }
    }
    assert!(worst < LEAKAGE_BOUND, "worst pairing {worst:.4} must clear the bound");
}

/// Refused deploys must leak nothing: the follow-up legitimate deploy
/// still finds the device intact.
fn attestation_cases<B: ServingBackend>(backend: B) {
    let label = backend.label();
    let good = TenancyBuilder::new("legit").region("fir").plan().unwrap();
    backend.deploy(&good).unwrap_or_else(|e| panic!("{label}: sealed plan must deploy: {e}"));

    let stripped: TenancyPlan =
        TenancyBuilder::new("anon").region("fft").plan().unwrap().with_attestation(None);
    let err = backend.deploy(&stripped).unwrap_err().to_string();
    assert!(err.contains("unattested"), "{label}: stripped plan must be refused, got: {err}");

    let donor = TenancyBuilder::new("donor").region("fir").plan().unwrap();
    let spliced = TenancyBuilder::new("mallory")
        .region("fft")
        .plan()
        .unwrap()
        .with_attestation(donor.attestation().copied());
    let err = backend.deploy(&spliced).unwrap_err().to_string();
    assert!(
        err.contains("does not verify"),
        "{label}: spliced tag must be refused, got: {err}"
    );

    let foreign = TenancyBuilder::new("rogue")
        .region("aes")
        .plan()
        .unwrap()
        .attest(&AttestationKey::from_seed(0xDEAD_BEEF));
    let err = backend.deploy(&foreign).unwrap_err().to_string();
    assert!(
        err.contains("does not verify"),
        "{label}: foreign-key signature must be refused, got: {err}"
    );

    // Nothing leaked: a second sealed plan still deploys.
    let again = TenancyBuilder::new("legit-2").region("huffman").plan().unwrap();
    backend
        .deploy(&again)
        .unwrap_or_else(|e| panic!("{label}: refusals must not leak resources: {e}"));
    backend.shutdown();
}

#[test]
fn unattested_and_tampered_plans_are_refused_on_every_backend() {
    attestation_cases(SerialBackend::new(System::empty("artifacts").unwrap()));
    attestation_cases(ShardedEngine::start(|| System::empty("artifacts")).unwrap());
    attestation_cases(FleetCluster::start(FleetConfig::new(1)).unwrap());
}
