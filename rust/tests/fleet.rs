//! Fleet-layer invariants, driven through the shared [`FleetCluster`]
//! front-end (admin over `&self` — serving never needs exclusive
//! scheduler ownership):
//!
//! - **Migration conservation**: every request submitted during a live
//!   cross-device migration gets exactly one reply (none lost, none
//!   duplicated — the engine-side `Metrics::requests` equals the count
//!   of `Ok` replies clients observed), and post-migration requests
//!   execute on the target device at the target's lifecycle epoch.
//! - **Placement**: bin-pack and spread respect per-device pblock
//!   capacity, with no cross-device state sharing (per-device VI
//!   numbering overlaps across devices precisely because nothing is
//!   shared).
//! - **Device churn**: graceful decommission keeps tenants serving;
//!   abrupt failure recovers them onto survivors.
//! - **Modeled scaling**: the same demand over 2 devices finishes in
//!   well under the 1-device makespan (the bench gates the full ≥1.8x).

use fpga_mt::cloud::{Ingress, Link};
use fpga_mt::coordinator::churn::{self, FleetChurnConfig};
use fpga_mt::fleet::{replay_fleet, FleetCluster, FleetConfig, PlacePolicy};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

fn fleet(devices: usize, policy: PlacePolicy) -> FleetCluster {
    let cfg = FleetConfig { policy, ..FleetConfig::new(devices) };
    FleetCluster::start(cfg).unwrap()
}

#[test]
fn migration_conserves_replies_and_lands_on_target_epoch() {
    let fleet = fleet(2, PlacePolicy::BinPack);
    let tenant = fleet.admit_tenant("mover", "aes").unwrap();
    assert_eq!(fleet.replicas(tenant)[0].device, 0, "bin-pack starts on device 0");
    // Let the deployment's reconfiguration window elapse so the client
    // load below measures migration behavior, not admission queueing.
    fleet.advance_clocks(10_000.0).unwrap();

    // Clients hammer the tenant while the control plane migrates it —
    // through the SAME shared front-end, no exclusive ownership handoff.
    let stop = Arc::new(AtomicBool::new(false));
    let mut clients = Vec::new();
    for c in 0..3 {
        let h = fleet.handle();
        let stop = Arc::clone(&stop);
        clients.push(std::thread::spawn(move || {
            let payload: Arc<[u8]> = vec![c as u8 + 1; 64].into();
            let (mut ok, mut err) = (0u64, 0u64);
            while !stop.load(Ordering::Relaxed) {
                match h.submit(tenant, Arc::clone(&payload)) {
                    Ok(resp) => {
                        ok += 1;
                        assert!(!resp.response.outputs.is_empty());
                    }
                    Err(_) => err += 1,
                }
            }
            (ok, err)
        }));
    }
    // Let traffic flow, then migrate live, then let it flow some more.
    std::thread::sleep(std::time::Duration::from_millis(30));
    let report = fleet.migrate_tenant(tenant, 0, 1).unwrap();
    assert_eq!(report.from, 0);
    assert_eq!(report.to, 1);
    assert_eq!(report.regions, 1);
    std::thread::sleep(std::time::Duration::from_millis(30));
    stop.store(true, Ordering::Relaxed);
    let mut ok_total = 0u64;
    for c in clients {
        let (ok, err) = c.join().unwrap();
        ok_total += ok;
        assert_eq!(err, 0, "a lone migration must be invisible to clients (retry covers it)");
    }
    assert!(ok_total > 0, "clients must have been served");

    // Post-migration requests execute on the target device at its epoch.
    let replicas = fleet.replicas(tenant);
    assert_eq!(replicas.len(), 1);
    assert_eq!(replicas[0].device, 1, "routes flipped to the target");
    let resp = fleet.submit(tenant, vec![9u8; 64]).unwrap();
    assert_eq!(resp.device, 1, "post-migration requests land on the target");
    // Engine-side ground truth: the epoch the target device actually
    // executed at must match the route table's view of the new replica.
    assert_eq!(
        resp.response.epoch,
        replicas[0].epoch,
        "post-migration requests execute on the target device's epoch"
    );
    assert_eq!(resp.epoch, resp.response.epoch, "router and engine agree on the epoch");
    assert_eq!(fleet.free_vrs(0).unwrap(), 6, "the source region was released");
    assert_eq!(fleet.migrations().unwrap(), 1);

    // Conservation: every Ok reply the clients counted was executed and
    // recorded exactly once, fleet-wide.
    let metrics = fleet.stop().unwrap();
    assert_eq!(
        metrics.requests,
        ok_total + 1,
        "each Ok reply recorded exactly once (none lost, none duplicated)"
    );
}

#[test]
fn binpack_fills_devices_in_order_and_respects_capacity() {
    let fleet = fleet(2, PlacePolicy::BinPack);
    let designs = ["huffman", "fft", "fpu", "aes", "canny", "fir"];
    let mut tenants = Vec::new();
    for i in 0..12 {
        let t = fleet.admit_tenant(&format!("t{i}"), designs[i % 6]).unwrap();
        tenants.push(t);
        let device = fleet.replicas(t)[0].device;
        assert_eq!(device, if i < 6 { 0 } else { 1 }, "tenant {i} must bin-pack");
    }
    assert_eq!(fleet.free_vrs(0).unwrap(), 0);
    assert_eq!(fleet.free_vrs(1).unwrap(), 0);
    // Capacity is per-device pblock accounting: a 13th tenant is refused.
    assert!(fleet.admit_tenant("overflow", "fir").is_err());
    // No cross-device state sharing: VI numbering restarts per device, so
    // the first tenant on each device holds the same VI id.
    let vi0 = fleet.replicas(tenants[0])[0].vi;
    let vi6 = fleet.replicas(tenants[6])[0].vi;
    assert_eq!(vi0, vi6, "independent hypervisors assign from the same id space");
    assert_ne!(
        fleet.replicas(tenants[0])[0].device,
        fleet.replicas(tenants[6])[0].device
    );
    // Releasing a tenant frees exactly its device's region.
    fleet.retire_tenant(tenants[0]).unwrap();
    assert_eq!(fleet.free_vrs(0).unwrap(), 1);
    assert_eq!(fleet.free_vrs(1).unwrap(), 0);
    fleet.stop().unwrap();
}

#[test]
fn spread_alternates_devices_and_serves_from_both() {
    let fleet = fleet(2, PlacePolicy::Spread);
    let a = fleet.admit_tenant("a", "fir").unwrap();
    let b = fleet.admit_tenant("b", "fft").unwrap();
    let da = fleet.replicas(a)[0].device;
    let db = fleet.replicas(b)[0].device;
    assert_ne!(da, db, "spread must not colocate the first two tenants");
    assert_eq!(fleet.submit(a, vec![1u8; 64]).unwrap().device, da);
    assert_eq!(fleet.submit(b, vec![2u8; 64]).unwrap().device, db);
    // A replica grows on the emptier device; round-robin then balances
    // the tenant's requests across devices.
    let replica = fleet.grow_tenant(a).unwrap();
    assert_ne!(replica.device, da, "the replica spreads to the other device");
    let devices: Vec<usize> =
        (0..4).map(|_| fleet.submit(a, vec![3u8; 32]).unwrap().device).collect();
    assert!(devices.contains(&da) && devices.contains(&replica.device), "{devices:?}");
    fleet.stop().unwrap();
}

#[test]
fn decommission_migrates_everything_and_failure_recovers() {
    let fleet = fleet(3, PlacePolicy::Spread);
    let designs = ["aes", "fir", "fft", "canny"];
    let tenants: Vec<_> = designs
        .iter()
        .enumerate()
        .map(|(i, d)| fleet.admit_tenant(&format!("t{i}"), d).unwrap())
        .collect();
    for &t in &tenants {
        fleet.submit(t, vec![5u8; 64]).unwrap();
    }
    // Gracefully decommission device 0: its tenants migrate, none stop
    // serving.
    let on_dev0: Vec<_> = tenants
        .iter()
        .filter(|&&t| fleet.replicas(t).iter().any(|r| r.device == 0))
        .copied()
        .collect();
    assert!(!on_dev0.is_empty(), "spread must have used device 0");
    let moved = fleet.decommission(0).unwrap();
    assert_eq!(moved as usize, on_dev0.len());
    assert!(!fleet.device_alive(0).unwrap());
    for &t in &tenants {
        let resp = fleet.submit(t, vec![6u8; 64]).unwrap();
        assert_ne!(resp.device, 0, "nothing may still route to the dead device");
    }
    // Abrupt failure of device 1: tenants recover onto device 2.
    if fleet.device_alive(1).unwrap() {
        fleet.fail_device(1).unwrap();
        assert!(!fleet.device_alive(1).unwrap());
        for &t in &tenants {
            let resp = fleet.submit(t, vec![7u8; 64]).unwrap();
            assert_eq!(resp.device, 2, "all traffic lands on the last survivor");
        }
    }
    assert!(fleet.migrations().unwrap() >= moved);
    fleet.stop().unwrap();
}

#[test]
fn two_devices_halve_the_modeled_makespan() {
    // The bench gates the full >=1.8x; this is the cheap regression: the
    // same 240-request demand over 2 devices must finish in well under
    // the 1-device makespan (modeled arrival clock = per-device demand
    // makespan).
    let designs = ["huffman", "fft", "fpu", "aes", "canny", "fir"];
    let makespan = |devices: usize| {
        let fleet = fleet(devices, PlacePolicy::Spread);
        let tenants: Vec<_> = (0..6)
            .map(|i| fleet.admit_tenant(&format!("t{i}"), designs[i]).unwrap())
            .collect();
        let payload: Arc<[u8]> = vec![3u8; 64].into();
        for i in 0..240 {
            fleet.submit(tenants[i % 6], Arc::clone(&payload)).unwrap();
        }
        let span = (0..devices).map(|d| fleet.clock_us(d).unwrap()).fold(0.0f64, f64::max);
        fleet.stop().unwrap();
        span
    };
    let one = makespan(1);
    let two = makespan(2);
    assert!(
        two < 0.65 * one,
        "2-device fleet must parallelize the demand (makespan {two:.0}µs vs {one:.0}µs)"
    );
}

#[test]
fn remote_ingress_shows_up_in_client_latency() {
    // A device behind the testbed Ethernet link: the front-end charges
    // the transfer per request, and the fleet-level percentiles (what a
    // client experiences) move while the device-side distribution does
    // not include it.
    let cfg = FleetConfig {
        ingress: Ingress::with_links(vec![Link::testbed_ethernet()]),
        ..FleetConfig::new(1)
    };
    let fleet = FleetCluster::start(cfg).unwrap();
    let tenant = fleet.admit_tenant("remote", "fir").unwrap();
    for _ in 0..4 {
        let resp = fleet.submit(tenant, vec![1u8; 100 * 1024]).unwrap();
        assert!(resp.ingress_us > 100.0, "remote link must charge transfer time");
    }
    let client_p50 = fleet.latency_percentile(50.0);
    let metrics = fleet.stop().unwrap();
    assert!(
        client_p50 > metrics.latency_percentile(50.0),
        "client latency must include the ingress link ({client_p50} vs {})",
        metrics.latency_percentile(50.0)
    );
}

#[test]
fn fleet_churn_replay_survives_device_and_tenant_churn() {
    let cfg = FleetChurnConfig { seed: 0xFEE7, events: 350, devices: 3 };
    let trace = churn::generate_fleet(&cfg);
    let fleet = fleet(3, PlacePolicy::Spread);
    let stats = replay_fleet(&fleet, &trace);
    assert!(stats.admitted >= 3, "admitted {}", stats.admitted);
    assert!(stats.served > 50, "served {}", stats.served);
    let metrics = fleet.stop().unwrap();
    assert_eq!(metrics.requests, stats.served, "every Ok reply recorded exactly once");
    assert!(metrics.latency_percentile(99.0) >= metrics.latency_percentile(50.0));
}
