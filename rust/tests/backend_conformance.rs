//! Backend conformance suite — the tentpole invariant of the unified
//! serving surface.
//!
//! One parameterized driver deploys the same tenancy plans, opens the
//! same sessions, and replays the same seeded trace (sync submissions,
//! an async pipelined wave, and per-session batches) through every
//! [`ServingBackend`]: the serial reference system, the sharded per-VR
//! engine, and a single-device fleet. The runs must agree byte for
//! byte:
//!
//! - every response identical — outputs, accelerator path, modeled
//!   timings, **and the lifecycle epoch** the serving region executed
//!   at;
//! - session targets identical — same VR indices, same pinned epochs;
//! - merged [`Metrics`] equal — requests, rejections, batches, byte
//!   counters, timing distributions, latency percentiles.
//!
//! This replaces the old pairwise serial-vs-sharded equivalence check:
//! with three implementations behind one trait, equivalence is a
//! property of the *surface*, not of one engine pair.

use fpga_mt::api::{
    BatchItem, SerialBackend, ServingBackend, Session, TenancyBuilder, TenancyPlan,
};
use fpga_mt::coordinator::metrics::Metrics;
use fpga_mt::coordinator::{Response, ShardedEngine, System};
use fpga_mt::fleet::{FleetCluster, FleetConfig};
use fpga_mt::telemetry::TelemetrySnapshot;
use fpga_mt::util::Rng;
use std::sync::Arc;

/// The tenancy every backend deploys: two single-region tenants plus the
/// paper's streaming pair (FPU chaining into AES on-chip).
fn plans() -> Vec<TenancyPlan> {
    vec![
        TenancyBuilder::new("alpha").region("fir").plan().unwrap(),
        TenancyBuilder::new("beta").region("fft").plan().unwrap(),
        TenancyBuilder::new("gamma").region("fpu").region("aes").stream(0, 1).plan().unwrap(),
    ]
}

/// `(tenant, region)` pairs a request may target (region indices are
/// positions in the tenant's deployment order).
const TARGETS: [(usize, usize); 4] = [(0, 0), (1, 0), (2, 0), (2, 1)];

fn seeded_payload(rng: &mut Rng) -> Arc<[u8]> {
    let len = 16 + rng.index(240);
    (0..len).map(|_| rng.below(256) as u8).collect::<Vec<u8>>().into()
}

struct Run {
    label: &'static str,
    /// Per-tenant session targets: `(vr, epoch)` in deployment order.
    targets: Vec<Vec<(usize, u64)>>,
    /// Every response, in trace order (sync wave, async wave, batches).
    responses: Vec<anyhow::Result<Response>>,
    metrics: Metrics,
    /// Request-path telemetry captured just before shutdown: span logs
    /// and per-tenant registries are conformance-gated exactly like the
    /// responses above.
    telemetry: TelemetrySnapshot,
}

/// Deploy, serve, and shut down one backend; everything seeded, so two
/// runs of this function differ only in the backend underneath.
fn drive<B: ServingBackend>(backend: B) -> Run {
    let label = backend.label();
    let tenants: Vec<_> =
        plans().iter().map(|p| backend.deploy(p).expect("deploy")).collect();
    // Let every deployment's reconfiguration window elapse so the trace
    // measures serving, not admission queueing behind deployment.
    backend.advance_clock(25_000.0).expect("advance");
    let sessions: Vec<Session> =
        tenants.iter().map(|&t| backend.session(t).expect("session")).collect();
    let targets = sessions
        .iter()
        .map(|s| s.targets().iter().map(|t| (t.vr, t.epoch)).collect())
        .collect();

    let mut rng = Rng::new(0x0C0FE);
    let mut responses = Vec::new();
    // 1. Sync wave: blocking submissions in seeded order.
    for _ in 0..48 {
        let (tenant, region) = TARGETS[rng.index(TARGETS.len())];
        responses.push(sessions[tenant].submit(region, seeded_payload(&mut rng)));
    }
    // 2. Async wave: submissions enter the arrival order immediately and
    //    complete out of band; results are collected in submission order.
    let mut pendings = Vec::new();
    for _ in 0..16 {
        let (tenant, region) = TARGETS[rng.index(TARGETS.len())];
        pendings.push(
            sessions[tenant]
                .submit_async(region, seeded_payload(&mut rng))
                .expect("submit_async"),
        );
    }
    responses.extend(pendings.into_iter().map(|p| p.wait()));
    // 3. One batch per session: a whole arrival slice in one dispatcher
    //    wakeup, results in slice order.
    for session in &sessions {
        let regions = session.targets().len();
        let batch: Vec<BatchItem> =
            (0..8).map(|i| BatchItem::new(i % regions, seeded_payload(&mut rng))).collect();
        responses.extend(session.submit_batch(&batch).expect("submit_batch"));
    }
    let telemetry = backend.telemetry_snapshot().expect("telemetry snapshot");
    let metrics = backend.shutdown();
    Run { label, targets, responses, metrics, telemetry }
}

fn assert_runs_identical(a: &Run, b: &Run) {
    let pair = format!("{} vs {}", a.label, b.label);
    assert_eq!(a.targets, b.targets, "{pair}: session targets (vr, epoch)");
    assert_eq!(a.responses.len(), b.responses.len(), "{pair}: trace length");
    let mut served = 0u64;
    for (i, (x, y)) in a.responses.iter().zip(&b.responses).enumerate() {
        match (x, y) {
            (Ok(x), Ok(y)) => {
                served += 1;
                assert_eq!(x.path, y.path, "{pair} request {i}: accelerator path");
                assert_eq!(x.epoch, y.epoch, "{pair} request {i}: serving epoch");
                assert_eq!(x.outputs.len(), y.outputs.len(), "{pair} request {i}");
                for (ta, tb) in x.outputs.iter().zip(&y.outputs) {
                    assert_eq!(ta.shape, tb.shape, "{pair} request {i}: output shape");
                    assert_eq!(ta.data, tb.data, "{pair} request {i}: output bytes");
                }
                assert_eq!(x.timing.io_us, y.timing.io_us, "{pair} request {i}: io model");
                assert_eq!(x.timing.noc_cycles, y.timing.noc_cycles, "{pair} request {i}: noc");
                assert_eq!(x.timing.bytes_in, y.timing.bytes_in, "{pair} request {i}");
                assert_eq!(x.timing.bytes_out, y.timing.bytes_out, "{pair} request {i}");
            }
            (Err(_), Err(_)) => {}
            (x, y) => panic!(
                "{pair} request {i}: acceptance diverged (ok={} vs ok={})",
                x.is_ok(),
                y.is_ok()
            ),
        }
    }
    assert!(served > 0, "{pair}: the trace must serve");
    let (ma, mb) = (&a.metrics, &b.metrics);
    assert_eq!(ma.requests, mb.requests, "{pair}: requests");
    assert_eq!(ma.rejected, mb.rejected, "{pair}: rejected");
    assert_eq!(ma.backpressured, mb.backpressured, "{pair}: backpressured");
    assert_eq!(ma.denied_ops, mb.denied_ops, "{pair}: denied_ops");
    assert_eq!(ma.batches, mb.batches, "{pair}: batches");
    assert_eq!(ma.bytes_in, mb.bytes_in, "{pair}: bytes_in");
    assert_eq!(ma.bytes_out, mb.bytes_out, "{pair}: bytes_out");
    assert_eq!(ma.io_us.count(), mb.io_us.count(), "{pair}: io_us count");
    assert!(
        (ma.io_us.mean() - mb.io_us.mean()).abs() < 1e-9,
        "{pair}: io_us mean {} vs {}",
        ma.io_us.mean(),
        mb.io_us.mean()
    );
    assert_eq!(ma.noc_cycles.max(), mb.noc_cycles.max(), "{pair}: noc_cycles max");
    for p in [50.0, 95.0, 99.0] {
        assert_eq!(
            ma.latency_percentile(p),
            mb.latency_percentile(p),
            "{pair}: p{p} latency (the sketch is order-independent, so exact)"
        );
    }
    // Telemetry conformance: spans carry *modeled* time only, so a
    // replayed trace's span log is byte-identical across engine shapes —
    // one wall-clock reading leaking into a span breaks this instantly.
    assert_eq!(
        a.telemetry.span_log(),
        b.telemetry.span_log(),
        "{pair}: request-path span logs must be byte-identical"
    );
    // And the per-tenant registries (counters + latency sketches) must
    // merge to the same state whether one thread or N shards recorded
    // them. Control events are engine-shape-specific (journal seqs exist
    // only where a journal does) and are deliberately not compared.
    assert_eq!(a.telemetry.tenants, b.telemetry.tenants, "{pair}: per-tenant registries");
}

fn serial_run() -> Run {
    drive(SerialBackend::new(System::empty("artifacts").unwrap()))
}

fn sharded_run() -> Run {
    drive(ShardedEngine::start(|| System::empty("artifacts")).unwrap())
}

fn fleet_run() -> Run {
    drive(FleetCluster::start(FleetConfig::new(1)).unwrap())
}

#[test]
fn all_three_backends_agree_on_one_trace() {
    let serial = serial_run();
    let sharded = sharded_run();
    let fleet = fleet_run();
    // The trace must exercise every surface: sync, async, and batches on
    // every backend (3 sessions -> 3 batch slices each run).
    assert_eq!(serial.metrics.batches, 3, "one batch per session");
    assert_eq!(serial.metrics.requests, 48 + 16 + 3 * 8);
    assert_runs_identical(&serial, &sharded);
    assert_runs_identical(&serial, &fleet);
    assert_runs_identical(&sharded, &fleet);
    // Telemetry content sanity on the shared trace (equality across
    // backends is asserted above): every served request left exactly one
    // trace, the registry's served total matches the engine metrics, and
    // the span log carries every serving-path phase.
    let served: u64 = serial.telemetry.tenants.values().map(|t| t.served).sum();
    assert_eq!(served, serial.metrics.requests, "registry served == metrics requests");
    assert_eq!(serial.telemetry.traces.len() as u64, serial.metrics.requests);
    let log = serial.telemetry.span_log();
    for phase in ["admit-wait", "reconfig-wait", "io-trip", "compute"] {
        assert!(log.contains(phase), "span log must carry {phase} spans");
    }
    assert!(log.contains("noc-stream"), "gamma's streaming chain must record NoC spans");
}

#[test]
fn sessions_expose_identical_tenancies_across_backends() {
    // Cheap standalone check (no serving trace): deploy-only
    // equivalence, so a deploy-path regression is reported even when the
    // serving trace is what breaks.
    fn deploy_targets<B: ServingBackend>(backend: B) -> Vec<Vec<(usize, u64)>> {
        let tenants: Vec<_> =
            plans().iter().map(|p| backend.deploy(p).expect("deploy")).collect();
        backend.advance_clock(25_000.0).expect("advance");
        let targets = tenants
            .iter()
            .map(|&t| {
                let session = backend.session(t).expect("session");
                session.targets().iter().map(|x| (x.vr, x.epoch)).collect()
            })
            .collect();
        backend.shutdown();
        targets
    }
    let serial = deploy_targets(SerialBackend::new(System::empty("artifacts").unwrap()));
    let fleet = deploy_targets(FleetCluster::start(FleetConfig::new(1)).unwrap());
    assert_eq!(serial, fleet, "deploys must land identical (vr, epoch) tenancies");
    assert_eq!(serial[2].len(), 2, "gamma holds two regions");
}

#[test]
fn foreign_probes_reject_identically_on_serial_and_sharded() {
    // Sessions cannot express a foreign-VI request (that is the point of
    // the surface), so access-monitor rejection equivalence is probed at
    // the raw envelope the engines share: the same case-study trace with
    // 25% foreign-VI requests mixed in must get identical accept/reject
    // decisions, identical served responses, and equal rejection counts
    // on the serial path and the sharded dispatcher.
    use fpga_mt::accel::CASE_STUDY;
    let mut rng = Rng::new(0xA11CE);
    let specs: Vec<(u16, usize)> = CASE_STUDY.iter().map(|s| (s.vi, s.vr)).collect();
    let trace: Vec<(u16, usize, Arc<[u8]>)> = (0..120)
        .map(|_| {
            let (mut vi, vr) = specs[rng.index(specs.len())];
            if rng.chance(0.25) {
                vi = (vi % 5) + 1; // sometimes lands on a foreign VI
            }
            (vi, vr, seeded_payload(&mut rng))
        })
        .collect();

    let mut sys = System::case_study("artifacts").unwrap();
    let serial: Vec<_> = trace.iter().map(|(vi, vr, p)| sys.submit(*vi, *vr, p)).collect();
    let serial_metrics = sys.metrics.clone();

    let engine = ShardedEngine::start(|| System::case_study("artifacts")).unwrap();
    let handle = engine.handle();
    let sharded: Vec<_> =
        trace.iter().map(|(vi, vr, p)| handle.call(*vi, *vr, Arc::clone(p))).collect();
    let sharded_metrics = engine.shutdown();

    for (i, (a, b)) in serial.iter().zip(&sharded).enumerate() {
        match (a, b) {
            (Ok(a), Ok(b)) => {
                assert_eq!(a.path, b.path, "request {i}");
                assert_eq!(a.timing.io_us, b.timing.io_us, "request {i}");
                for (ta, tb) in a.outputs.iter().zip(&b.outputs) {
                    assert_eq!(ta.data, tb.data, "request {i}: output bytes");
                }
            }
            (Err(_), Err(_)) => {}
            (a, b) => panic!(
                "request {i}: engines disagree on acceptance (ok={} vs ok={})",
                a.is_ok(),
                b.is_ok()
            ),
        }
    }
    assert!(serial_metrics.rejected > 0, "the trace must contain foreign probes");
    assert_eq!(serial_metrics.rejected, sharded_metrics.rejected);
    assert_eq!(serial_metrics.requests, sharded_metrics.requests);
    assert_eq!(serial_metrics.bytes_in, sharded_metrics.bytes_in);
}

#[test]
fn stale_ticket_replay_and_region_squat_reject_identically_on_every_backend() {
    // Red-team conformance: a fixed hostile mini-trace driven through
    // the uniform `AttackSurface` (the same entry points the red-team
    // replay uses) must produce the identical accept/refuse sequence —
    // same positions, same error strings — and identical counters on the
    // serial backend, the sharded engine, and a single-device fleet.
    use fpga_mt::api::DEPLOY_SETTLE_US;
    use fpga_mt::coordinator::redteam::AttackSurface;
    use fpga_mt::hypervisor::LifecycleOp;

    fn fmt_req(r: anyhow::Result<Response>) -> String {
        match r {
            Ok(resp) => format!("ok(path={:?}, epoch={})", resp.path, resp.epoch),
            Err(e) => format!("err({e})"),
        }
    }

    fn hostile_mini_case<B: ServingBackend + AttackSurface>(
        backend: B,
    ) -> (Vec<String>, Metrics, TelemetrySnapshot) {
        let payload: Arc<[u8]> = Arc::from(vec![7u8; 64]);
        let mut log: Vec<String> = Vec::new();

        // Victim deploys one FIR region; its session pins (vr, epoch).
        let plan = TenancyBuilder::new("victim").region("fir").plan().unwrap();
        let tenant = backend.deploy(&plan).expect("deploy");
        AttackSurface::advance(&backend, DEPLOY_SETTLE_US).expect("advance");
        let session = backend.session(tenant).expect("session");
        let (vr, epoch) = {
            let t = &session.targets()[0];
            (t.vr, t.epoch)
        };

        // 1. The pinned ticket is valid: the epoch-scoped submit serves.
        log.push(fmt_req(backend.submit(1, vr, Some(epoch), &payload)));
        // 2. The victim's own growth retargets the region (epoch bump);
        //    replaying the captured ticket must now be refused as stale.
        let grown = backend
            .apply_op(&LifecycleOp::Grow { vi: 1, stream_src: Some(vr), design: "aes".into() })
            .expect("grow");
        log.push(fmt_req(backend.submit(1, vr, Some(epoch), &payload)));
        // 3. The victim releases the grown region; a second tenant tries
        //    to squat on it with a bare Program (no allocation) — the
        //    hypervisor must refuse the op (denied_ops counter).
        let freed = match grown {
            fpga_mt::hypervisor::LifecycleOutcome::Vr(new_vr) => new_vr,
            other => panic!("grow returns Vr, got {other:?}"),
        };
        AttackSurface::advance(&backend, DEPLOY_SETTLE_US).expect("advance");
        backend.apply_op(&LifecycleOp::Release { vi: 1, vr: freed }).expect("release");
        backend
            .apply_op(&LifecycleOp::CreateVi { name: "squatter".into() })
            .expect("create squatter");
        let squat = backend.apply_op(&LifecycleOp::Program {
            vi: 2,
            vr: freed,
            design: "fft".into(),
            dest: None,
        });
        log.push(match squat {
            Ok(o) => format!("ok({o:?})"),
            Err(e) => format!("err({e})"),
        });
        // 4. The squatter probes the victim's live region directly — the
        //    access monitor must refuse (rejected counter).
        log.push(fmt_req(backend.submit(2, vr, None, &payload)));
        let telemetry = backend.telemetry_snapshot().expect("telemetry snapshot");
        (log, backend.shutdown(), telemetry)
    }

    let (serial_log, serial_metrics, serial_tel) =
        hostile_mini_case(SerialBackend::new(System::empty("artifacts").unwrap()));
    let (sharded_log, sharded_metrics, sharded_tel) =
        hostile_mini_case(ShardedEngine::start(|| System::empty("artifacts")).unwrap());
    let (fleet_log, fleet_metrics, fleet_tel) =
        hostile_mini_case(FleetCluster::start(FleetConfig::new(1)).unwrap());

    assert_eq!(serial_log, sharded_log, "serial vs sharded: hostile trace diverged");
    assert_eq!(serial_log, fleet_log, "serial vs fleet: hostile trace diverged");
    assert!(serial_log[0].starts_with("ok("), "the fresh ticket must serve: {}", serial_log[0]);
    assert!(
        serial_log[1].contains("stale session"),
        "the replayed ticket must be refused as stale: {}",
        serial_log[1]
    );
    assert!(
        serial_log[2].contains("is not held by"),
        "the squat must be refused by the ownership precheck: {}",
        serial_log[2]
    );
    assert!(
        serial_log[3].contains("does not own"),
        "the foreign probe must be refused by the access monitor: {}",
        serial_log[3]
    );
    for (label, m) in
        [("serial", &serial_metrics), ("sharded", &sharded_metrics), ("fleet", &fleet_metrics)]
    {
        assert_eq!(m.requests, serial_metrics.requests, "{label}: requests");
        assert_eq!(m.rejected, serial_metrics.rejected, "{label}: rejected");
        assert_eq!(m.denied_ops, serial_metrics.denied_ops, "{label}: denied_ops");
        assert!(m.rejected >= 2, "{label}: stale replay + foreign probe must both count");
        assert!(m.denied_ops >= 1, "{label}: the refused squat must count");
    }
    // Telemetry attribution under hostility: the refusals land under the
    // *attacking* tenant in every backend's registry — the refused squat
    // under the squatter's denied_ops, the foreign probe under its
    // rejected — and the registries agree across engine shapes.
    assert_eq!(serial_tel.tenants, sharded_tel.tenants, "serial vs sharded: registries");
    assert_eq!(serial_tel.tenants, fleet_tel.tenants, "serial vs fleet: registries");
    let squatter = &serial_tel.tenants[&2];
    assert_eq!(squatter.denied_ops, 1, "the refused squat attributes to the squatter");
    assert!(squatter.rejected >= 1, "the foreign probe attributes to the prober");
    assert!(serial_tel.tenants[&1].rejected >= 1, "the stale replay attributes to tenant 1");
}

#[test]
fn stale_sessions_reject_identically_on_every_backend() {
    // After the tenant's tenancy is torn down and a new tenant takes the
    // same region, an old session must be refused — with the engines
    // counting the refusal as a rejection — on every backend. (The
    // lifecycle goes through each backend's own control-plane surface.)
    fn stale_case<B: ServingBackend>(
        backend: B,
        churn: impl FnOnce(&B),
    ) -> (String, u64, u64) {
        let plan = TenancyBuilder::new("victim").region("fir").plan().unwrap();
        let tenant = backend.deploy(&plan).expect("deploy");
        backend.advance_clock(25_000.0).expect("advance");
        let session = backend.session(tenant).expect("session");
        assert!(session.submit(0, vec![1u8; 64]).is_ok());
        churn(&backend);
        let err = session.submit(0, vec![1u8; 64]).unwrap_err().to_string();
        let metrics = backend.shutdown();
        (err, metrics.requests, metrics.rejected)
    }

    let serial = stale_case(
        SerialBackend::new(System::empty("artifacts").unwrap()),
        |backend| {
            backend.with_system(|sys| {
                use fpga_mt::hypervisor::{LifecycleOp, LifecycleOutcome};
                sys.core.timing.advance_clock(25_000.0);
                sys.lifecycle(&LifecycleOp::DestroyVi { vi: 1 }).unwrap();
                let intruder =
                    match sys.lifecycle(&LifecycleOp::CreateVi { name: "x".into() }).unwrap() {
                        LifecycleOutcome::Vi(vi) => vi,
                        _ => unreachable!(),
                    };
                sys.lifecycle(&LifecycleOp::Allocate { vi: intruder }).unwrap();
                sys.lifecycle(&LifecycleOp::Program {
                    vi: intruder,
                    vr: 0,
                    design: "aes".into(),
                    dest: None,
                })
                .unwrap();
            });
        },
    );
    let fleet = stale_case(FleetCluster::start(FleetConfig::new(1)).unwrap(), |backend| {
        backend.advance_clocks(25_000.0).unwrap();
        backend.retire_tenant(0).unwrap();
        backend.admit_tenant("x", "aes").unwrap();
    });
    for (label, (err, requests, rejected)) in [("serial", serial), ("fleet", fleet)] {
        assert_eq!(requests, 1, "{label}: only the pre-churn submission serves");
        assert!(rejected >= 1, "{label}: the stale submission must count as a rejection");
        assert!(
            err.contains("stale session") || err.contains("does not own"),
            "{label}: refusal must be staleness or access gating, got: {err}"
        );
    }
}
