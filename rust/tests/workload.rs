//! Open-loop workload subsystem: determinism, calibration, scoring, and
//! the controller's placement discipline.
//!
//! - **Determinism**: the seeded arrival stream is a pure function of
//!   (sources, seed) — two generators replay byte-identical event
//!   streams, and different seeds diverge.
//! - **Calibration**: thinning converges to the process's mean rate, and
//!   a flash crowd holds at its multiplier during the hold phase.
//! - **Open-loop acceptance**: an underprovisioned tenant's p99 grows
//!   without bound window over window while its arrival timestamps stay
//!   exactly on schedule — the property a closed-loop driver cannot
//!   exhibit, and the reason the SLO bench is trustworthy.
//! - **Placement discipline**: elastic grows land only on alive devices;
//!   a refused grow leaves the replica set untouched; shed load is
//!   dropped at the driver and never reaches the fleet admission path.
//! - **Backends**: the same driver serves through a real sharded-engine
//!   session and through the routed fleet front-end.

use fpga_mt::api::{ServingBackend, TenancyBuilder};
use fpga_mt::coordinator::{ShardedEngine, System};
use fpga_mt::fleet::{FleetCluster, FleetConfig};
use fpga_mt::util::QuantileSketch;
use fpga_mt::workload::arrivals::{
    ArrivalStream, FlashCrowd, PayloadDist, Poisson, TenantSource,
};
use fpga_mt::workload::driver::{FleetTransport, ModelTransport, SessionTransport};
use fpga_mt::workload::scenario::{self, Scenario};
use fpga_mt::workload::slo::{score_sketch, SloTarget};
use fpga_mt::workload::{ControlMode, OpenLoop};

fn two_tenant_sources() -> Vec<TenantSource> {
    vec![
        TenantSource {
            process: Box::new(Poisson { rate_per_s: 4_000.0 }),
            payload: PayloadDist::heavy_tailed(),
        },
        TenantSource {
            process: Box::new(FlashCrowd {
                base_per_s: 1_500.0,
                spike_start_us: 100_000.0,
                ramp_us: 20_000.0,
                hold_us: 80_000.0,
                multiplier: 5.0,
            }),
            payload: PayloadDist { min_bytes: 64, max_bytes: 512, alpha: 1.5 },
        },
    ]
}

#[test]
fn same_seed_replays_a_byte_identical_event_stream() {
    let mut a = ArrivalStream::new(two_tenant_sources(), 42);
    let mut b = ArrivalStream::new(two_tenant_sources(), 42);
    let ea = a.events_until(300_000.0);
    let eb = b.events_until(300_000.0);
    assert!(ea.len() > 1_000, "stream produced {} events; expected a dense trace", ea.len());
    assert_eq!(ea, eb, "same seed must replay the identical stream");
    assert_eq!(
        format!("{ea:?}"),
        format!("{eb:?}"),
        "debug renderings (timestamps, tenants, payload sizes) must match byte for byte"
    );
    // And the stream actually depends on the seed.
    let ec = ArrivalStream::new(two_tenant_sources(), 43).events_until(300_000.0);
    assert_ne!(ea, ec, "a different seed must produce a different stream");
}

#[test]
fn thinning_converges_to_the_poisson_mean_rate() {
    let sources = vec![TenantSource {
        process: Box::new(Poisson { rate_per_s: 2_000.0 }),
        payload: PayloadDist::heavy_tailed(),
    }];
    let n = ArrivalStream::new(sources, 7).events_until(5_000_000.0).len() as f64;
    let expect = 2_000.0 * 5.0;
    assert!(
        (n - expect).abs() / expect < 0.05,
        "5 s at 2000/s produced {n} arrivals; expected within 5% of {expect}"
    );
}

#[test]
fn flash_crowd_holds_at_its_multiplier() {
    let sources = vec![TenantSource {
        process: Box::new(FlashCrowd {
            base_per_s: 1_000.0,
            spike_start_us: 1_000_000.0,
            ramp_us: 100_000.0,
            hold_us: 1_000_000.0,
            multiplier: 4.0,
        }),
        payload: PayloadDist::heavy_tailed(),
    }];
    let events = ArrivalStream::new(sources, 11).events_until(2_100_000.0);
    let base = events.iter().filter(|a| a.t_us < 1_000_000.0).count() as f64;
    let hold = events
        .iter()
        .filter(|a| a.t_us >= 1_100_000.0 && a.t_us < 2_100_000.0)
        .count() as f64;
    let ratio = hold / base;
    assert!(
        (3.2..=4.8).contains(&ratio),
        "hold-phase rate was {ratio:.2}x base; expected ~4x (base {base}, hold {hold})"
    );
}

#[test]
fn scorer_matches_hand_computed_sketches() {
    // 99 requests at 10 µs plus one 10 ms straggler: the rank-99 sample
    // sits in integer bucket 10, whose midpoint is exactly 10.5.
    let mut sketch = QuantileSketch::new();
    for _ in 0..99 {
        sketch.add(10.0);
    }
    sketch.add(10_000.0);
    let target = SloTarget { p99_us: 50.0, availability: 0.99 };
    let good = score_sketch(0, target, &sketch, 100, 0);
    assert_eq!(good.observed_p99_us, 10.5);
    assert!(good.p99_met && good.availability_met && good.attained());
    assert_eq!(good.observed_availability, 1.0);

    // Two stragglers push rank 99 into the 10 ms bucket: p99 blows the
    // bound even though 98% of requests were fast.
    let mut tail = QuantileSketch::new();
    for _ in 0..98 {
        tail.add(10.0);
    }
    tail.add(10_000.0);
    tail.add(10_000.0);
    let slow = score_sketch(1, target, &tail, 98, 2);
    assert!(slow.observed_p99_us > 9_000.0);
    assert!(!slow.p99_met && !slow.attained());
    // Availability 0.98 against a 0.99 floor burns 2x the error budget.
    assert!(!slow.availability_met);
    assert!((slow.burn_rate - 2.0).abs() < 1e-9);
}

/// The acceptance property from the issue: a deliberately
/// underprovisioned tenant shows unbounded queueing growth in its
/// observed p99 while its arrival timestamps stay on schedule.
#[test]
fn underprovisioned_p99_grows_without_bound_while_arrivals_stay_on_schedule() {
    let sources = vec![TenantSource {
        process: Box::new(Poisson { rate_per_s: 20_000.0 }),
        payload: PayloadDist::heavy_tailed(),
    }];
    // One server at 100 µs/request = 10k/s capacity against 20k/s
    // offered: utilization 2.0, so backlog grows linearly forever.
    let mut stream = ArrivalStream::new(sources, 3);
    let mut driver = OpenLoop::new(&[1]);
    let mut transport = ModelTransport::new(100.0);

    let mut window_p99 = Vec::new();
    let mut last_arrival = 0.0f64;
    for w in 1..=4 {
        let horizon = w as f64 * 250_000.0;
        for a in stream.events_until(horizon) {
            driver.offer(&a, &mut transport);
            last_arrival = a.t_us;
        }
        let obs = driver.end_window(horizon);
        window_p99.push(obs[0].p99_us);
    }
    // Unbounded growth: every window's p99 strictly dominates the last,
    // and the final window is far beyond any fixed bound.
    for pair in window_p99.windows(2) {
        assert!(
            pair[1] > pair[0] * 1.25,
            "window p99s {window_p99:?} are not growing without bound"
        );
    }
    assert!(window_p99[3] > 100_000.0, "after 1 s at 2x overload, p99 {:.0} µs should exceed 100 ms", window_p99[3]);
    // ...while the arrival clock never slipped: the last arrival is on
    // schedule just shy of the horizon, not throttled behind the
    // backlog.
    assert!(
        last_arrival > 995_000.0 && last_arrival < 1_000_000.0,
        "last arrival {last_arrival:.1} µs drifted off the open-loop schedule"
    );
    // A closed-loop driver would have served ~horizon/service requests;
    // the open-loop driver accepted them all.
    assert_eq!(driver.flows[0].arrivals, transport.served + driver.flows[0].shed);
    assert!(driver.flows[0].arrivals as f64 > 18_000.0);
}

#[test]
fn elastic_grows_land_only_on_alive_devices_and_failed_grows_change_nothing() {
    let cluster = FleetCluster::start(FleetConfig::new(2)).unwrap();
    let tenant = cluster.admit_tenant("elastic", "fir").unwrap();
    cluster.advance_clocks(20_000.0).unwrap();
    cluster.fail_device(1).unwrap();

    // Grow until the fleet refuses: every accepted replica must sit on
    // an alive device, and every refusal must leave the set untouched.
    let mut accepted = 0;
    for _ in 0..8 {
        let before: Vec<(usize, usize)> =
            cluster.replicas(tenant).iter().map(|r| (r.device, r.vr)).collect();
        match cluster.grow_tenant(tenant) {
            Ok(replica) => {
                accepted += 1;
                assert!(
                    cluster.device_alive(replica.device).unwrap(),
                    "grow placed a replica on dead device {}",
                    replica.device
                );
            }
            Err(_) => {
                let after: Vec<(usize, usize)> =
                    cluster.replicas(tenant).iter().map(|r| (r.device, r.vr)).collect();
                assert_eq!(before, after, "a refused grow must not mutate the replica set");
            }
        }
    }
    assert!(accepted >= 1, "one device still had free VRs; at least one grow must land");
    for r in cluster.replicas(tenant) {
        assert!(cluster.device_alive(r.device).unwrap());
    }
    cluster.stop().unwrap();
}

#[test]
fn shrink_is_the_inverse_of_a_cross_device_grow() {
    let cluster = FleetCluster::start(FleetConfig::new(2)).unwrap();
    let tenant = cluster.admit_tenant("pulse", "aes").unwrap();
    cluster.advance_clocks(20_000.0).unwrap();
    let entry: Vec<(usize, usize)> =
        cluster.replicas(tenant).iter().map(|r| (r.device, r.vr)).collect();

    // Spread placement grows onto the unoccupied device...
    let grown = cluster.grow_tenant(tenant).unwrap();
    assert_ne!(grown.device, entry[0].0, "spread must prefer the empty device");
    assert!(cluster.replicas(tenant).len() > entry.len());
    // ...and shrink releases exactly that device, restoring the entry set.
    assert_eq!(cluster.shrink_tenant(tenant).unwrap(), grown.device);
    let after: Vec<(usize, usize)> =
        cluster.replicas(tenant).iter().map(|r| (r.device, r.vr)).collect();
    assert_eq!(after, entry, "shrink must restore the pre-grow replica set");
    // Shrink is per-device and refuses to drop the last replica.
    assert!(cluster.shrink_tenant(tenant).is_err());
    cluster.stop().unwrap();
}

#[test]
fn shed_load_never_reaches_the_fleet_admission_path() {
    let cluster = FleetCluster::start(FleetConfig::new(1)).unwrap();
    let tenant = cluster.admit_tenant("shed", "fir").unwrap();
    cluster.advance_clocks(20_000.0).unwrap();

    let sources = vec![TenantSource {
        process: Box::new(Poisson { rate_per_s: 2_000.0 }),
        payload: PayloadDist::heavy_tailed(),
    }];
    let mut stream = ArrivalStream::new(sources, 5);
    let mut driver = OpenLoop::new(&[1]);
    driver.set_shed_fraction(0, 1.0);
    let mut transport = FleetTransport::new(&cluster, vec![tenant]);
    for a in stream.events_until(100_000.0) {
        driver.offer(&a, &mut transport);
    }
    let flow = &driver.flows[0];
    assert!(flow.arrivals > 100 && flow.shed == flow.arrivals && flow.served == 0);
    let metrics = cluster.stop().unwrap();
    assert_eq!(
        metrics.requests, 0,
        "shed requests must be dropped at the driver, not admitted and rejected"
    );
}

#[test]
fn session_transport_serves_an_open_loop_over_the_sharded_engine() {
    let engine = ShardedEngine::start(|| System::empty("artifacts")).unwrap();
    let plan = TenancyBuilder::new("wl").region("fir").plan().unwrap();
    let tenant = engine.deploy(&plan).unwrap();
    engine.advance_clock(25_000.0).unwrap();

    let mut transport = SessionTransport::open(&engine, &[tenant]).unwrap();
    let sources = vec![TenantSource {
        process: Box::new(Poisson { rate_per_s: 1_000.0 }),
        payload: PayloadDist { min_bytes: 64, max_bytes: 256, alpha: 1.3 },
    }];
    let mut stream = ArrivalStream::new(sources, 9);
    let mut driver = OpenLoop::new(&[1]);
    for a in stream.events_until(200_000.0) {
        driver.offer(&a, &mut transport);
    }
    let flow = &driver.flows[0];
    assert!(flow.arrivals > 100, "expected a dense trace, got {}", flow.arrivals);
    assert_eq!(flow.served, flow.arrivals, "well-provisioned open loop refuses nothing");
    assert!(flow.latency.percentile(99.0) > 0.0);
    let metrics = engine.shutdown();
    assert_eq!(metrics.requests, flow.served, "every offered request hit the real engine");
}

#[test]
fn flash_crowd_scenario_predictive_beats_static_at_equal_devices() {
    let sc = Scenario::flash_crowd().smoke();
    let stat = scenario::run(&sc, ControlMode::Static, 0xBEEF).unwrap();
    let pred = scenario::run(&sc, ControlMode::Predictive, 0xBEEF).unwrap();

    assert_eq!(
        stat.arrivals_total, pred.arrivals_total,
        "open-loop demand must not depend on the controller"
    );
    let spike_static = &stat.report.tenants[0];
    let spike_pred = &pred.report.tenants[0];
    assert!(
        !spike_static.p99_met,
        "static allocation should miss the spike p99 ({} µs target {})",
        spike_static.observed_p99_us, spike_static.target.p99_us
    );
    assert!(
        spike_pred.p99_met,
        "predictive should meet the spike p99 ({} µs target {})",
        spike_pred.observed_p99_us, spike_pred.target.p99_us
    );
    assert!(pred.grows_ok >= 1);
    assert!(pred.report.attainment() >= stat.report.attainment());
    assert!(
        spike_pred.observed_p99_us < spike_static.observed_p99_us,
        "growing ahead of the spike must cut the observed tail"
    );
}

#[test]
fn steady_state_scenario_attains_every_slo() {
    let sc = Scenario::steady_state().smoke();
    let out = scenario::run(&sc, ControlMode::Predictive, 0xFEED).unwrap();
    assert!(out.arrivals_total > 0);
    assert_eq!(
        out.report.attainment(),
        1.0,
        "a provisioned steady state must attain every SLO:\n{}",
        out.report.render()
    );
}
