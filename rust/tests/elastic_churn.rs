//! Elastic tenant churn under live load — the tentpole invariants:
//!
//! - **Churn equivalence**: replaying one seeded allocate/program/serve/
//!   grow/release trace through the serial engine and the sharded engine
//!   yields byte-identical responses, identical op outcomes (down to the
//!   allocated VR indices), and equal merged `Metrics` — including
//!   requests that land inside a reconfiguration window (queued *and*
//!   backpressure-rejected ones).
//! - **Isolation regression**: after a region is released and
//!   re-allocated to a different tenant, the new owner is unreachable via
//!   the old owner's stream wiring, the old owner is locked out at the
//!   access monitor, and a stale admission ticket (minted before the
//!   release) is rejected at the shard ingress.
//! - **Liveness**: hot-drain under concurrent client load loses no
//!   replies and never deadlocks.

use fpga_mt::coordinator::churn::{self, ChurnConfig};
use fpga_mt::coordinator::metrics::Metrics;
use fpga_mt::coordinator::server::Engine;
use fpga_mt::coordinator::shard::{serve_admitted, ShardEnv, ShardPlan, ShardRequest};
use fpga_mt::coordinator::timing::Gate;
use fpga_mt::coordinator::{ShardedEngine, System};
use fpga_mt::hypervisor::{LifecycleOp, LifecycleOutcome};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

fn assert_metrics_equal(serial: &Metrics, sharded: &Metrics) {
    assert_eq!(serial.requests, sharded.requests, "requests");
    assert_eq!(serial.rejected, sharded.rejected, "rejected");
    assert_eq!(serial.backpressured, sharded.backpressured, "backpressured");
    assert_eq!(serial.bytes_in, sharded.bytes_in, "bytes_in");
    assert_eq!(serial.bytes_out, sharded.bytes_out, "bytes_out");
    assert_eq!(serial.io_us.count(), sharded.io_us.count(), "io_us count");
    assert!(
        (serial.io_us.mean() - sharded.io_us.mean()).abs() < 1e-9,
        "io_us mean {} vs {}",
        serial.io_us.mean(),
        sharded.io_us.mean()
    );
    assert_eq!(serial.noc_cycles.max(), sharded.noc_cycles.max(), "noc_cycles max");
}

#[test]
fn churn_trace_serial_and_sharded_agree() {
    let cfg = ChurnConfig { seed: 0xE1A57, events: 380, foreign_probe: 0.15 };
    let events = churn::generate(&cfg);

    let serial = Engine::start(|| System::empty("artifacts")).unwrap();
    let serial_replay = churn::replay(&serial.handle(), &events);
    let serial_metrics = serial.stop();

    let sharded = ShardedEngine::start(|| System::empty("artifacts")).unwrap();
    let sharded_replay = churn::replay(&sharded.handle(), &events);
    let sharded_metrics = sharded.stop();

    // Lifecycle outcomes identical, down to the allocated VR indices.
    assert_eq!(serial_replay.outcomes.len(), sharded_replay.outcomes.len());
    for (i, (a, b)) in
        serial_replay.outcomes.iter().zip(&sharded_replay.outcomes).enumerate()
    {
        match (a, b) {
            (Ok(x), Ok(y)) => assert_eq!(x, y, "op {i}: outcomes diverged"),
            (Err(_), Err(_)) => {}
            _ => panic!(
                "op {i}: engines disagree on success (serial ok={}, sharded ok={})",
                a.is_ok(),
                b.is_ok()
            ),
        }
    }

    // Responses byte-identical, including modeled timings.
    assert_eq!(serial_replay.responses.len(), sharded_replay.responses.len());
    let mut served = 0u64;
    for (i, (a, b)) in
        serial_replay.responses.iter().zip(&sharded_replay.responses).enumerate()
    {
        match (a, b) {
            (Ok(a), Ok(b)) => {
                served += 1;
                assert_eq!(a.path, b.path, "request {i}: accelerator path");
                assert_eq!(a.outputs.len(), b.outputs.len(), "request {i}");
                for (ta, tb) in a.outputs.iter().zip(&b.outputs) {
                    assert_eq!(ta.shape, tb.shape, "request {i}: output shape");
                    assert_eq!(ta.data, tb.data, "request {i}: outputs must be byte-identical");
                }
                assert_eq!(a.timing.io_us, b.timing.io_us, "request {i}: io model");
                assert_eq!(a.timing.noc_cycles, b.timing.noc_cycles, "request {i}: noc");
                assert_eq!(a.timing.bytes_in, b.timing.bytes_in, "request {i}");
                assert_eq!(a.timing.bytes_out, b.timing.bytes_out, "request {i}");
            }
            (Err(_), Err(_)) => {}
            _ => panic!(
                "request {i}: engines disagree on acceptance (serial ok={}, sharded ok={})",
                a.is_ok(),
                b.is_ok()
            ),
        }
    }

    // The trace must actually exercise the interesting paths.
    assert!(served > 50, "served only {served}");
    assert_eq!(serial_metrics.requests, served);
    assert!(serial_metrics.rejected > 0, "foreign probes must be rejected");
    assert!(
        serial_metrics.backpressured > 0,
        "bursts past the backlog must hit reconfiguration backpressure"
    );
    assert_metrics_equal(&serial_metrics, &sharded_metrics);
}

#[test]
fn reconfig_window_gates_grow_and_release_identically_on_both_engines() {
    // Churn edge cases: a `Grow` whose stream source is still inside its
    // reconfiguration window, and a `Release` against a region that is
    // still draining that window, are both refused by the shared
    // control-plane precheck — with the *same* accept/reject decisions on
    // the serial and the sharded engine, at the same trace positions.
    fn drive(h: &fpga_mt::coordinator::server::EngineHandle) -> Vec<bool> {
        let mut decisions = Vec::new();
        let vi = match h.lifecycle(LifecycleOp::CreateVi { name: "edge".into() }).unwrap() {
            LifecycleOutcome::Vi(vi) => vi,
            other => panic!("expected Vi, got {other:?}"),
        };
        let vr = match h.lifecycle(LifecycleOp::Allocate { vi }).unwrap() {
            LifecycleOutcome::Vr(vr) => vr,
            other => panic!("expected Vr, got {other:?}"),
        };
        // Opens VR's reconfiguration window.
        h.lifecycle(LifecycleOp::Program { vi, vr, design: "fpu".into(), dest: None }).unwrap();
        // 1. Grow streaming from a still-reconfiguring source: refused.
        decisions.push(
            h.lifecycle(LifecycleOp::Grow { vi, stream_src: Some(vr), design: "aes".into() })
                .is_ok(),
        );
        // 2. Release of the still-draining region: refused.
        decisions.push(h.lifecycle(LifecycleOp::Release { vi, vr }).is_ok());
        // The refused ops must not have disturbed the tenancy: the region
        // still serves its tenant.
        decisions.push(h.call(vi, vr, vec![3u8; 64]).is_ok());
        // Once the window elapses both ops are accepted.
        h.advance_clock(20_000.0).unwrap();
        decisions.push(
            h.lifecycle(LifecycleOp::Grow { vi, stream_src: Some(vr), design: "aes".into() })
                .is_ok(),
        );
        h.advance_clock(20_000.0).unwrap();
        decisions.push(h.lifecycle(LifecycleOp::Release { vi, vr }).is_ok());
        decisions
    }

    let serial = Engine::start(|| System::empty("artifacts")).unwrap();
    let serial_decisions = drive(&serial.handle());
    serial.stop();

    let sharded = ShardedEngine::start(|| System::empty("artifacts")).unwrap();
    let sharded_decisions = drive(&sharded.handle());
    sharded.stop();

    assert_eq!(
        serial_decisions,
        vec![false, false, true, true, true],
        "grow-in-window and release-while-draining must be refused, then accepted"
    );
    assert_eq!(serial_decisions, sharded_decisions, "engines must gate identically");
}

#[test]
fn released_region_is_isolated_from_its_previous_owner() {
    let mut sys = System::case_study("artifacts").unwrap();
    // VI3's FPU (VR2) streams into its AES region (VR3) over a wired link.
    let before = sys.submit(3, 2, &[7u8; 64]).unwrap();
    assert_eq!(before.path, vec!["fpu".to_string(), "aes".to_string()]);

    // Mint an admission ticket against VR3's *current* epoch, as if a
    // request were in flight at the moment of the release.
    let old_plan = ShardPlan::snapshot(&sys.hv, 3);
    let stale_adm = match sys.core.timing.admit_vr(1_000, 3, old_plan.epoch) {
        Gate::Admitted(adm) => adm,
        Gate::Busy { .. } => panic!("no window is open"),
    };

    // VI3 shrinks; a new tenant takes over the same physical region.
    sys.lifecycle(&LifecycleOp::Release { vi: 3, vr: 3 }).unwrap();
    let intruder = match sys.lifecycle(&LifecycleOp::CreateVi { name: "intruder".into() }) {
        Ok(LifecycleOutcome::Vi(vi)) => vi,
        other => panic!("expected Vi, got {other:?}"),
    };
    let vr = match sys.lifecycle(&LifecycleOp::Allocate { vi: intruder }) {
        Ok(LifecycleOutcome::Vr(vr)) => vr,
        other => panic!("expected Vr, got {other:?}"),
    };
    assert_eq!(vr, 3, "free pool must hand back the released region");
    sys.lifecycle(&LifecycleOp::Program {
        vi: intruder,
        vr: 3,
        design: "aes".into(),
        dest: None,
    })
    .unwrap();

    // 1. The new owner cannot be reached via the old owner's stream
    //    wiring: FPU no longer chains, and the direct link is gone.
    let plan2 = ShardPlan::snapshot(&sys.hv, 2);
    assert_eq!(plan2.stream_dest, None, "stale Wrapper registers must not chain");
    assert!(!sys.core.noc.has_direct(2, 3), "release must unwire the direct link");
    let after = sys.submit(3, 2, &[7u8; 64]).unwrap();
    assert_eq!(after.path, vec!["fpu".to_string()], "no cross-tenant streaming");
    assert_eq!(after.timing.noc_cycles, 0);

    // 2. The old owner is locked out at the access monitor.
    let rejected_before = sys.metrics.rejected;
    assert!(sys.submit(3, 3, &[1u8; 16]).is_err());
    assert_eq!(sys.metrics.rejected, rejected_before + 1);

    // 3. The stale admission ticket is rejected at the shard ingress:
    //    epoch moved on release + re-allocate + re-program.
    let new_plan = ShardPlan::snapshot(&sys.hv, 3);
    assert!(new_plan.epoch > old_plan.epoch, "lifecycle must bump the epoch");
    let mut metrics = Metrics::default();
    let env = ShardEnv {
        runtime: sys.runtime.as_ref(),
        io_cfg: &sys.io_cfg,
        tel: &sys.telemetry,
    };
    let payload = [9u8; 32];
    let trace = fpga_mt::telemetry::TraceCtx::new(0, intruder, 3, stale_adm.epoch);
    let result = serve_admitted(
        ShardRequest { vi: intruder, payload: &payload, adm: stale_adm, trace },
        &new_plan,
        &env,
        &mut sys.core,
        &mut metrics,
    );
    let err = result.err().expect("stale admission must not serve");
    assert!(err.to_string().contains("stale admission"), "got: {err}");
    assert_eq!(metrics.rejected, 1, "stale tickets count as rejections");
}

#[test]
fn hot_drain_under_concurrent_load_conserves_replies() {
    // Five tenants hammer their regions while the control plane churns
    // VI5's region (release -> re-allocate -> re-program) repeatedly.
    // Every call must return (Ok or Err) and every Ok must be counted.
    let engine = ShardedEngine::start(|| System::case_study("artifacts")).unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let mut clients = Vec::new();
    for (vi, vr) in [(1u16, 0usize), (2, 1), (3, 3), (4, 4), (5, 5)] {
        let h = engine.handle();
        let stop = Arc::clone(&stop);
        clients.push(std::thread::spawn(move || {
            let payload: Arc<[u8]> = vec![vr as u8 + 1; 64].into();
            let mut ok = 0u64;
            while !stop.load(Ordering::Relaxed) {
                if h.call(vi, vr, Arc::clone(&payload)).is_ok() {
                    ok += 1;
                }
            }
            ok
        }));
    }
    let ctl = engine.handle();
    for round in 0..6 {
        // Wait out the previous round's programming window: a release
        // against a still-draining region is refused by the control plane.
        ctl.advance_clock(10_000.0).unwrap();
        ctl.lifecycle(LifecycleOp::Release { vi: 5, vr: 5 })
            .unwrap_or_else(|e| panic!("round {round}: release failed: {e}"));
        let vr = match ctl.lifecycle(LifecycleOp::Allocate { vi: 5 }) {
            Ok(LifecycleOutcome::Vr(vr)) => vr,
            other => panic!("round {round}: expected Vr, got {other:?}"),
        };
        assert_eq!(vr, 5, "round {round}: the freed region is the only free one");
        ctl.lifecycle(LifecycleOp::Program { vi: 5, vr: 5, design: "fir".into(), dest: None })
            .unwrap_or_else(|e| panic!("round {round}: program failed: {e}"));
    }
    stop.store(true, Ordering::Relaxed);
    let ok_total: u64 = clients.into_iter().map(|c| c.join().unwrap()).sum();
    let metrics = engine.stop();
    assert_eq!(metrics.requests, ok_total, "every Ok reply must be counted exactly once");
    assert!(ok_total > 0, "clients must have been served");
}
