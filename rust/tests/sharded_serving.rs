//! Sharded-engine contention tests: concurrency invariants the backend
//! conformance suite's single-threaded trace cannot exercise.
//!
//! (Cross-backend equivalence — byte-identical responses and equal
//! merged `Metrics` on one trace — lives in
//! `rust/tests/backend_conformance.rs`, run against the serial system,
//! the sharded engine, *and* the fleet through the one `ServingBackend`
//! surface.)
//!
//! - >= 4 client threads per VI hammering the sharded engine concurrently
//!   lose nothing: every request is served, counters conserve;
//! - concurrent streaming (FPU -> AES) stays isolated from direct traffic
//!   to the destination shard.

use fpga_mt::accel::CASE_STUDY;
use fpga_mt::coordinator::{ShardedEngine, System};
use std::sync::Arc;

#[test]
fn contention_four_clients_per_vi_conserves_all_requests() {
    const CLIENTS_PER_VI: usize = 4;
    const ROUNDS: usize = 3;
    let engine = ShardedEngine::start(|| System::case_study("artifacts")).unwrap();
    let payload: Arc<[u8]> =
        (0..128u32).map(|i| (i * 7 % 256) as u8).collect::<Vec<u8>>().into();
    let mut joins = Vec::new();
    // One spec per VI (skip fpu so VI3 uses its AES region): 5 VIs x 4
    // clients x 3 rounds.
    for spec in CASE_STUDY.iter().filter(|s| s.name != "fpu") {
        for _client in 0..CLIENTS_PER_VI {
            let h = engine.handle();
            let p = Arc::clone(&payload);
            let (vi, vr) = (spec.vi, spec.vr);
            joins.push(std::thread::spawn(move || {
                for _ in 0..ROUNDS {
                    let resp = h.call(vi, vr, Arc::clone(&p)).unwrap();
                    assert!(!resp.outputs.is_empty());
                    assert!(resp.outputs[0].data.iter().all(|v| v.is_finite()));
                }
            }));
        }
    }
    for j in joins {
        j.join().unwrap();
    }
    let m = engine.stop();
    let expect = (5 * CLIENTS_PER_VI * ROUNDS) as u64;
    assert_eq!(m.requests, expect);
    assert_eq!(m.rejected, 0);
    assert_eq!(m.bytes_in, expect * 128);
    assert_eq!(m.io_us.count(), expect);
}

#[test]
fn concurrent_streaming_responses_are_reproducible() {
    // All six shards loaded at once, including the FPU -> AES streaming
    // chain: per-payload outputs must not depend on scheduling.
    let engine = ShardedEngine::start(|| System::case_study("artifacts")).unwrap();
    let mut joins = Vec::new();
    for spec in CASE_STUDY.iter() {
        let h = engine.handle();
        let (vi, vr) = (spec.vi, spec.vr);
        let payload: Arc<[u8]> = vec![vr as u8 + 1; 96].into();
        joins.push(std::thread::spawn(move || {
            let resps: Vec<_> =
                (0..4).map(|_| h.call(vi, vr, Arc::clone(&payload)).unwrap()).collect();
            for r in &resps {
                assert_eq!(
                    r.outputs[0].data, resps[0].outputs[0].data,
                    "same payload to one shard must give one answer"
                );
            }
            resps[0].path.clone()
        }));
    }
    let paths: Vec<Vec<String>> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    assert!(paths.iter().any(|p| p.len() == 2), "the FPU chain must have streamed");
    let m = engine.stop();
    assert_eq!(m.requests, 6 * 4);
    assert_eq!(m.rejected, 0);
}
