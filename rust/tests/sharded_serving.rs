//! Serial-vs-sharded serving equivalence and contention tests (the
//! tentpole invariants of the per-VR request pipeline):
//!
//! - replaying an identical request trace through the serial engine and
//!   the sharded engine yields identical per-request outputs, modeled
//!   timings, and merged `Metrics` totals (requests, rejected, bytes);
//! - >= 4 client threads per VI hammering the sharded engine concurrently
//!   lose nothing: every request is served, counters conserve;
//! - concurrent streaming (FPU -> AES) stays isolated from direct traffic
//!   to the destination shard.

use fpga_mt::accel::CASE_STUDY;
use fpga_mt::coordinator::server::Engine;
use fpga_mt::coordinator::{ShardedEngine, System};
use fpga_mt::util::Rng;
use std::sync::Arc;

/// Deterministic request trace over the case-study tenancy:
/// `(vi, vr, payload)` triples, optionally with foreign-VI requests mixed
/// in (which both engines must reject identically).
fn trace(n: usize, seed: u64, with_foreign: bool) -> Vec<(u16, usize, Arc<[u8]>)> {
    let mut rng = Rng::new(seed);
    let specs: Vec<(u16, usize)> = CASE_STUDY.iter().map(|s| (s.vi, s.vr)).collect();
    (0..n)
        .map(|_| {
            let (mut vi, vr) = specs[rng.index(specs.len())];
            if with_foreign && rng.chance(0.25) {
                vi = (vi % 5) + 1; // sometimes lands on a foreign VI
            }
            let len = 16 + rng.index(240);
            let payload: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
            (vi, vr, Arc::from(payload))
        })
        .collect()
}

#[test]
fn sharded_engine_matches_serial_on_identical_trace() {
    let t = trace(120, 0xA11CE, true);

    let serial = Engine::start(|| System::case_study("artifacts")).unwrap();
    let sh = serial.handle();
    let serial_resps: Vec<_> =
        t.iter().map(|(vi, vr, p)| sh.call(*vi, *vr, Arc::clone(p))).collect();
    let serial_metrics = serial.stop();

    let sharded = ShardedEngine::start(|| System::case_study("artifacts")).unwrap();
    let h = sharded.handle();
    let sharded_resps: Vec<_> =
        t.iter().map(|(vi, vr, p)| h.call(*vi, *vr, Arc::clone(p))).collect();
    let sharded_metrics = sharded.stop();

    let mut served = 0u64;
    for (i, (a, b)) in serial_resps.iter().zip(&sharded_resps).enumerate() {
        match (a, b) {
            (Ok(a), Ok(b)) => {
                served += 1;
                assert_eq!(a.path, b.path, "request {i}: accelerator path");
                assert_eq!(a.outputs.len(), b.outputs.len(), "request {i}");
                for (ta, tb) in a.outputs.iter().zip(&b.outputs) {
                    assert_eq!(ta.shape, tb.shape, "request {i}: output shape");
                    assert_eq!(ta.data, tb.data, "request {i}: outputs must be byte-identical");
                }
                // Modeled timings are deterministic per request id; real
                // compute wall time is the only field allowed to differ.
                assert_eq!(a.timing.io_us, b.timing.io_us, "request {i}: io model");
                assert_eq!(a.timing.noc_cycles, b.timing.noc_cycles, "request {i}: noc");
                assert_eq!(a.timing.bytes_in, b.timing.bytes_in, "request {i}");
                assert_eq!(a.timing.bytes_out, b.timing.bytes_out, "request {i}");
            }
            (Err(_), Err(_)) => {}
            (a, b) => panic!(
                "request {i}: engines disagree on acceptance (serial ok={}, sharded ok={})",
                a.is_ok(),
                b.is_ok()
            ),
        }
    }
    assert!(served > 0, "trace must contain served requests");
    assert!(serial_metrics.rejected > 0, "trace must contain rejections");

    // Merged metrics totals equal the serial trace exactly.
    assert_eq!(serial_metrics.requests, sharded_metrics.requests);
    assert_eq!(serial_metrics.rejected, sharded_metrics.rejected);
    assert_eq!(serial_metrics.bytes_in, sharded_metrics.bytes_in);
    assert_eq!(serial_metrics.bytes_out, sharded_metrics.bytes_out);
    assert_eq!(serial_metrics.requests, served);
    // Distributions: same sample count, same mean up to merge fp noise.
    assert_eq!(serial_metrics.io_us.count(), sharded_metrics.io_us.count());
    assert!((serial_metrics.io_us.mean() - sharded_metrics.io_us.mean()).abs() < 1e-9);
    assert_eq!(serial_metrics.noc_cycles.max(), sharded_metrics.noc_cycles.max());
}

#[test]
fn contention_four_clients_per_vi_conserves_all_requests() {
    const CLIENTS_PER_VI: usize = 4;
    const ROUNDS: usize = 3;
    let engine = ShardedEngine::start(|| System::case_study("artifacts")).unwrap();
    let payload: Arc<[u8]> =
        (0..128u32).map(|i| (i * 7 % 256) as u8).collect::<Vec<u8>>().into();
    let mut joins = Vec::new();
    // One spec per VI (skip fpu so VI3 uses its AES region): 5 VIs x 4
    // clients x 3 rounds.
    for spec in CASE_STUDY.iter().filter(|s| s.name != "fpu") {
        for _client in 0..CLIENTS_PER_VI {
            let h = engine.handle();
            let p = Arc::clone(&payload);
            let (vi, vr) = (spec.vi, spec.vr);
            joins.push(std::thread::spawn(move || {
                for _ in 0..ROUNDS {
                    let resp = h.call(vi, vr, Arc::clone(&p)).unwrap();
                    assert!(!resp.outputs.is_empty());
                    assert!(resp.outputs[0].data.iter().all(|v| v.is_finite()));
                }
            }));
        }
    }
    for j in joins {
        j.join().unwrap();
    }
    let m = engine.stop();
    let expect = (5 * CLIENTS_PER_VI * ROUNDS) as u64;
    assert_eq!(m.requests, expect);
    assert_eq!(m.rejected, 0);
    assert_eq!(m.bytes_in, expect * 128);
    assert_eq!(m.io_us.count(), expect);
}

#[test]
fn concurrent_streaming_responses_are_reproducible() {
    // All six shards loaded at once, including the FPU -> AES streaming
    // chain: per-payload outputs must not depend on scheduling.
    let engine = ShardedEngine::start(|| System::case_study("artifacts")).unwrap();
    let mut joins = Vec::new();
    for spec in CASE_STUDY.iter() {
        let h = engine.handle();
        let (vi, vr) = (spec.vi, spec.vr);
        let payload: Arc<[u8]> = vec![vr as u8 + 1; 96].into();
        joins.push(std::thread::spawn(move || {
            let resps: Vec<_> =
                (0..4).map(|_| h.call(vi, vr, Arc::clone(&payload)).unwrap()).collect();
            for r in &resps {
                assert_eq!(
                    r.outputs[0].data, resps[0].outputs[0].data,
                    "same payload to one shard must give one answer"
                );
            }
            resps[0].path.clone()
        }));
    }
    let paths: Vec<Vec<String>> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    assert!(paths.iter().any(|p| p.len() == 2), "the FPU chain must have streamed");
    let m = engine.stop();
    assert_eq!(m.requests, 6 * 4);
    assert_eq!(m.rejected, 0);
}
