//! Integration: every model executed through the runtime must match the
//! independent Rust-native oracle. With the PJRT backend this gate caught
//! HLO-text/parser semantic drift; with the native interpreter backend
//! (see DESIGN.md, "substitutions") it pins the runtime's wire formats —
//! shapes, output arity, byte round-trips — against the oracles.

use fpga_mt::accel::native;
use fpga_mt::runtime::{Runtime, Tensor};

fn runtime() -> Runtime {
    Runtime::load_dir("artifacts").expect("runtime boots without artifacts")
}

fn close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let denom = y.abs().max(1.0);
        assert!(
            (x - y).abs() / denom < tol,
            "{what}[{i}]: {x} vs {y} (tol {tol})"
        );
    }
}

#[test]
fn all_models_load() {
    let rt = runtime();
    for name in ["aes", "canny", "fft", "fir", "fpu", "huffman"] {
        assert!(rt.has_model(name), "missing {name}");
    }
}

#[test]
fn fir_artifact_matches_oracle() {
    let rt = runtime();
    let x: Vec<f32> = (0..1024).map(|i| ((i * 37 % 97) as f32) / 19.0 - 2.0).collect();
    let h: Vec<f32> = (0..16).map(|i| ((i as f32) - 7.5) / 16.0).collect();
    let out = rt
        .execute("fir", &[Tensor::vec1(x.clone()), Tensor::vec1(h.clone())])
        .unwrap();
    close(&out[0].data, &native::fir(&x, &h), 1e-4, "fir");
}

#[test]
fn fft_artifact_matches_oracle() {
    let rt = runtime();
    let re: Vec<f32> = (0..8 * 256).map(|i| ((i * 13 % 41) as f32) / 10.0 - 2.0).collect();
    let im: Vec<f32> = (0..8 * 256).map(|i| ((i * 7 % 29) as f32) / 10.0 - 1.4).collect();
    let out = rt
        .execute(
            "fft",
            &[Tensor::new(vec![8, 256], re.clone()), Tensor::new(vec![8, 256], im.clone())],
        )
        .unwrap();
    for row in 0..8 {
        let (er, ei) = native::dft_row(&re[row * 256..(row + 1) * 256], &im[row * 256..(row + 1) * 256]);
        close(&out[0].data[row * 256..(row + 1) * 256], &er, 2e-2, "fft re");
        close(&out[1].data[row * 256..(row + 1) * 256], &ei, 2e-2, "fft im");
    }
}

#[test]
fn fpu_artifact_matches_oracle() {
    let rt = runtime();
    let a: Vec<f32> = (0..4096).map(|i| ((i % 101) as f32) / 7.0 - 7.0).collect();
    let b: Vec<f32> = (0..4096).map(|i| ((i % 97) as f32) / 9.0 - 5.0).collect();
    let c: Vec<f32> = (0..4096).map(|i| ((i % 89) as f32) / 11.0 - 4.0).collect();
    let out = rt
        .execute(
            "fpu",
            &[Tensor::vec1(a.clone()), Tensor::vec1(b.clone()), Tensor::vec1(c.clone())],
        )
        .unwrap();
    close(&out[0].data, &native::fpu(&a, &b, &c), 1e-4, "fpu");
}

#[test]
fn canny_artifact_matches_oracle() {
    let rt = runtime();
    let img: Vec<f32> = (0..128 * 128)
        .map(|i| {
            let (y, x) = (i / 128, i % 128);
            if (x / 16 + y / 16) % 2 == 0 { 200.0 } else { 30.0 }
        })
        .collect();
    let out = rt.execute("canny", &[Tensor::new(vec![128, 128], img.clone())]).unwrap();
    close(&out[0].data, &native::canny_magnitude(&img, 128, 128), 2e-2, "canny");
}

#[test]
fn aes_artifact_matches_oracle_fips_key() {
    let rt = runtime();
    let blocks: Vec<f32> = (0..256).map(|i| i as f32).collect();
    let key: [u8; 16] = core::array::from_fn(|i| i as u8);
    let rks = native::aes_key_expand(&key);
    let rk_f: Vec<f32> = rks.iter().flatten().map(|&b| b as f32).collect();
    let out = rt
        .execute("aes", &[Tensor::new(vec![16, 16], blocks.clone()), Tensor::new(vec![11, 16], rk_f)])
        .unwrap();
    let got = out[0].to_bytes();
    for blk in 0..16 {
        let mut b = [0u8; 16];
        for i in 0..16 {
            b[i] = blocks[blk * 16 + i] as u8;
        }
        let expect = native::aes_encrypt_block(&b, &rks);
        assert_eq!(&got[blk * 16..blk * 16 + 16], &expect, "block {blk}");
    }
}

#[test]
fn aes_artifact_random_key() {
    let rt = runtime();
    let key: [u8; 16] = core::array::from_fn(|i| (i as u8).wrapping_mul(53).wrapping_add(11));
    let rks = native::aes_key_expand(&key);
    let rk_f: Vec<f32> = rks.iter().flatten().map(|&b| b as f32).collect();
    let blocks: Vec<f32> = (0..256).map(|i| ((i * 29 + 5) % 256) as f32).collect();
    let out = rt
        .execute("aes", &[Tensor::new(vec![16, 16], blocks.clone()), Tensor::new(vec![11, 16], rk_f)])
        .unwrap();
    let got = out[0].to_bytes();
    for blk in 0..16 {
        let mut b = [0u8; 16];
        for i in 0..16 {
            b[i] = blocks[blk * 16 + i] as u8;
        }
        assert_eq!(&got[blk * 16..blk * 16 + 16], &native::aes_encrypt_block(&b, &rks), "block {blk}");
    }
}

#[test]
fn huffman_artifact_expands_through_table() {
    let rt = runtime();
    let sym: Vec<f32> = (0..2048).map(|i| ((i * 31) % 256) as f32).collect();
    let table: Vec<f32> = (0..256).map(|i| (255 - i) as f32).collect();
    let out = rt
        .execute("huffman", &[Tensor::vec1(sym.clone()), Tensor::vec1(table.clone())])
        .unwrap();
    let expect: Vec<f32> = sym.iter().map(|&s| table[s as usize]).collect();
    close(&out[0].data, &expect, 1e-6, "huffman");
}

#[test]
fn huffman_end_to_end_decode_pipeline() {
    // Rust canonical decode (control path) + artifact expansion (tensor
    // path) — the full substituted Huffman accelerator.
    let rt = runtime();
    let text = b"the quick brown fox jumps over the lazy dog; the dog sleeps";
    let cb = fpga_mt::accel::huffman::Codebook::from_frequencies(
        &fpga_mt::accel::huffman::frequencies(text),
    )
    .unwrap();
    let (bits, n) = cb.encode(text).unwrap();
    let symbols = cb.decode(&bits, n).unwrap();
    assert_eq!(symbols, text);
    // Tensor stage: map symbols through an identity table on the FPGA.
    let mut sym_f: Vec<f32> = symbols.iter().map(|&b| b as f32).collect();
    sym_f.resize(2048, 0.0);
    let table: Vec<f32> = (0..256).map(|i| i as f32).collect();
    let out = rt.execute("huffman", &[Tensor::vec1(sym_f), Tensor::vec1(table)]).unwrap();
    let decoded: Vec<u8> = out[0].data[..text.len()].iter().map(|&v| v as u8).collect();
    assert_eq!(decoded, text);
}
