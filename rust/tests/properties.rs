//! System-level property tests and failure injection.
//!
//! Invariants checked under randomized workloads:
//! - flit conservation: every sent flit is delivered or rejected, never
//!   duplicated or lost, on every topology flavor;
//! - per-source FIFO ordering survives arbitrary cross traffic;
//! - the access monitor never leaks a foreign-VI packet;
//! - hypervisor allocation never double-books a VR and always recovers
//!   after exhaustion/release churn;
//! - estimate models are monotone in width and radix;
//! - the batched NoC engine is cycle-for-cycle identical to the retained
//!   fixpoint reference engine on random topologies and traffic;
//! - the per-column partitioned NoC gate streams cycle- and
//!   byte-identically to the single-lock gate (and the fixpoint oracle)
//!   on seeded multi-column hop traces, across partition configs.

use fpga_mt::coordinator::design_footprint;
use fpga_mt::device::Device;
use fpga_mt::estimate::{router_fmax_mhz, router_power_mw, router_resources, RouterConfig};
use fpga_mt::hypervisor::{Hypervisor, LifecycleOp, LifecycleOutcome, Policy, VrStatus};
use fpga_mt::noc::{FixpointSim, NocSim, Payload, Topology};
use fpga_mt::placer;
use fpga_mt::util::prop::forall;
use fpga_mt::util::Rng;

fn random_topology(rng: &mut Rng) -> Topology {
    match rng.below(3) {
        0 => Topology::single_column(1 + rng.below(8) as usize),
        1 => Topology::double_column(2 + rng.below(10) as usize),
        _ => {
            let n = 3 + rng.below(9) as usize;
            // Fold count derives from n: any column count in 1..=n is a
            // legal multi-column deployment (the seed hard-coded 3 here,
            // never exercising deeper folds).
            Topology::multi_column(n, 1 + rng.below(n as u64) as usize)
        }
    }
}

#[test]
fn flit_conservation_on_random_topologies() {
    forall("flit conservation", 48, |rng| {
        let topo = random_topology(rng);
        let n_vrs = topo.n_vrs();
        let mut sim = NocSim::new(topo);
        // Random ownership: a few VIs spread over the VRs.
        let n_vis = 1 + rng.below(4) as u16;
        for vr in 0..n_vrs {
            sim.assign_vr(vr, rng.below(n_vis as u64) as u16);
        }
        let mut sent = 0u64;
        for _ in 0..rng.range_u64(1, 300) {
            let src = rng.index(n_vrs);
            let dst = rng.index(n_vrs);
            if dst == src {
                continue;
            }
            // Random claimed VI: sometimes foreign (must be rejected).
            let vi = rng.below(n_vis as u64) as u16;
            let h = sim.header_for(vi, dst);
            sim.send(src, h, vec![rng.below(256) as u8], 0);
            sent += 1;
        }
        assert!(sim.drain(100_000), "network failed to drain");
        assert_eq!(
            sim.stats.delivered + sim.stats.rejected,
            sent,
            "lost or duplicated flits"
        );
        assert_eq!(sim.in_flight(), 0);
    });
}

#[test]
fn access_monitor_never_leaks_foreign_packets() {
    forall("access monitor soundness", 48, |rng| {
        let topo = random_topology(rng);
        let n_vrs = topo.n_vrs();
        let mut sim = NocSim::new(topo);
        for vr in 0..n_vrs {
            sim.assign_vr(vr, (vr % 3) as u16);
        }
        for _ in 0..rng.range_u64(1, 200) {
            let src = rng.index(n_vrs);
            let dst = rng.index(n_vrs);
            if dst == src {
                continue;
            }
            let vi = rng.below(4) as u16;
            let h = sim.header_for(vi, dst);
            sim.send(src, h, Payload::empty(), 0);
        }
        sim.drain(100_000);
        // Every delivered flit's VI must match its VR's owner.
        for (vr, state) in sim.vrs.iter().enumerate() {
            for f in &state.delivered {
                assert_eq!(
                    Some(f.header.vi_id),
                    state.owner_vi,
                    "VR{vr} accepted a foreign packet"
                );
            }
        }
    });
}

#[test]
fn per_source_fifo_order_survives_cross_traffic() {
    forall("fifo order", 32, |rng| {
        let topo = Topology::single_column(3);
        let mut sim = NocSim::new(topo);
        for vr in 0..6 {
            sim.assign_vr(vr, 1);
        }
        // Tracked stream: VR0 -> VR5 with sequence numbers.
        let n = 1 + rng.below(40) as u32;
        let h = sim.header_for(1, 5);
        for seq in 0..n {
            sim.send(0, h, Payload::empty(), seq);
            // Random cross traffic every cycle.
            for _ in 0..rng.below(3) {
                let src = 1 + rng.index(4);
                let dst = rng.index(6);
                if dst != src && dst != 5 {
                    let hh = sim.header_for(1, dst);
                    sim.send(src, hh, Payload::empty(), 0);
                }
            }
            sim.step();
        }
        sim.drain(100_000);
        let seqs: Vec<u32> = sim.vrs[5].delivered.iter().map(|f| f.seq).collect();
        let mut sorted = seqs.clone();
        sorted.sort_unstable();
        assert_eq!(seqs, sorted, "stream reordered");
        assert_eq!(seqs.len(), n as usize);
    });
}

#[test]
fn hypervisor_never_double_books_under_churn() {
    forall("allocation churn", 32, |rng| {
        let device = Device::vu9p();
        let (topo, fp) = placer::case_study_floorplan(&device).unwrap();
        let mut sim = NocSim::new(topo.clone());
        let policy = if rng.chance(0.5) { Policy::FirstFit } else { Policy::AdjacentFirst };
        let mut hv = Hypervisor::new(topo, fp, policy);
        let vis: Vec<u16> = (0..3).map(|i| hv.create_vi(&format!("t{i}"))).collect();
        for _ in 0..rng.range_u64(10, 120) {
            let vi = vis[rng.index(vis.len())];
            if rng.chance(0.6) {
                let _ = hv.allocate_vr(vi, &mut sim);
            } else {
                let held: Vec<usize> = hv.vis[&vi].vrs.clone();
                if !held.is_empty() {
                    let vr = held[rng.index(held.len())];
                    hv.release_vr(vi, vr, &mut sim).unwrap();
                }
            }
            // Invariant: each allocated VR appears in exactly one VI's list.
            let mut owners = vec![0u32; hv.vrs.len()];
            for v in &vis {
                for &vr in &hv.vis[v].vrs {
                    owners[vr] += 1;
                }
            }
            for (vr, &count) in owners.iter().enumerate() {
                let allocated = hv.vrs[vr].status != VrStatus::Free;
                assert_eq!(count, u32::from(allocated), "VR{vr} ownership corrupt");
                // NoC access monitor mirrors hypervisor state.
                assert_eq!(sim.vrs[vr].owner_vi.is_some(), allocated);
            }
        }
    });
}

#[test]
fn exhaustion_recovers_after_release() {
    let device = Device::vu9p();
    let (topo, fp) = placer::case_study_floorplan(&device).unwrap();
    let mut sim = NocSim::new(topo.clone());
    let mut hv = Hypervisor::new(topo, fp, Policy::FirstFit);
    let vi = hv.create_vi("hog");
    let held: Vec<usize> = (0..6).map(|_| hv.allocate_vr(vi, &mut sim).unwrap()).collect();
    assert!(hv.allocate_vr(vi, &mut sim).is_err()); // injected exhaustion
    hv.release_vr(vi, held[3], &mut sim).unwrap();
    assert_eq!(hv.allocate_vr(vi, &mut sim).unwrap(), held[3]); // recovered
}

#[test]
fn estimate_models_are_monotone() {
    forall("model monotonicity", 16, |rng| {
        let dev = Device::vu9p();
        let w = [32u32, 64, 128][rng.index(3)];
        let w2 = w * 2;
        for ports in [3u32, 4] {
            let a = RouterConfig::bufferless(ports, w);
            let b = RouterConfig::bufferless(ports, w2);
            assert!(router_resources(&b).lut > router_resources(&a).lut);
            assert!(router_resources(&b).ff > router_resources(&a).ff);
            assert!(router_power_mw(&b).total_mw() > router_power_mw(&a).total_mw());
            assert!(router_fmax_mhz(&b, &dev) <= router_fmax_mhz(&a, &dev));
        }
        // Radix monotonicity at fixed width.
        let r3 = RouterConfig::bufferless(3, w);
        let r4 = RouterConfig::bufferless(4, w);
        assert!(router_resources(&r4).lut > router_resources(&r3).lut);
        assert!(router_fmax_mhz(&r4, &dev) < router_fmax_mhz(&r3, &dev));
    });
}

#[test]
fn batched_engine_matches_fixpoint_reference() {
    // The tentpole invariant: the batched flat-state engine performs the
    // exact same movement decisions as the seed's fixpoint engine — same
    // deliveries, same rejections, same latency/waiting distributions,
    // same per-VR delivery order, and even the same number of fixpoint
    // passes — on random topologies under random cross-VI traffic with
    // direct links wired where possible.
    forall("engine equivalence", 48, |rng| {
        let topo = random_topology(rng);
        let n_vrs = topo.n_vrs();
        let mut new_sim = NocSim::new(topo.clone());
        let mut ref_sim = FixpointSim::new(topo);
        let n_vis = 1 + rng.below(4) as u16;
        for vr in 0..n_vrs {
            let vi = rng.below(n_vis as u64) as u16;
            new_sim.assign_vr(vr, vi);
            ref_sim.assign_vr(vr, vi);
        }
        // Wire a direct link between the two VRs of router 0 half the time.
        let mut direct_src = None;
        if n_vrs >= 2 && rng.chance(0.5) {
            new_sim.wire_direct(0, 1).unwrap();
            ref_sim.wire_direct(0, 1).unwrap();
            direct_src = Some(0usize);
        }
        // Interleave sends and steps so traffic lands mid-flight.
        for step in 0..rng.range_u64(5, 120) {
            for _ in 0..rng.below(4) {
                let src = rng.index(n_vrs);
                let dst = rng.index(n_vrs);
                if dst == src {
                    continue;
                }
                let vi = rng.below(n_vis as u64) as u16;
                let h = new_sim.header_for(vi, dst);
                let payload = vec![rng.below(256) as u8];
                new_sim.send(src, h, payload.clone(), step as u32);
                ref_sim.send(src, h, payload, step as u32);
            }
            if direct_src == Some(0) && rng.chance(0.3) {
                let vi = rng.below(n_vis as u64) as u16;
                let h = new_sim.header_for(vi, 1);
                new_sim.send_direct(0, h, vec![7], step as u32);
                ref_sim.send_direct(0, h, vec![7], step as u32);
            }
            new_sim.step();
            ref_sim.step();
            assert_eq!(new_sim.in_flight(), ref_sim.in_flight(), "in-flight diverged");
            assert_eq!(new_sim.passes, ref_sim.passes, "pass count diverged");
        }
        assert_eq!(new_sim.drain(100_000), ref_sim.drain(100_000));
        assert_eq!(new_sim.stats.delivered, ref_sim.stats.delivered);
        assert_eq!(new_sim.stats.rejected, ref_sim.stats.rejected);
        assert_eq!(new_sim.stats.direct_delivered, ref_sim.stats.direct_delivered);
        assert_eq!(new_sim.stats.latency.mean(), ref_sim.stats.latency.mean());
        assert_eq!(new_sim.stats.latency.max(), ref_sim.stats.latency.max());
        assert_eq!(new_sim.stats.waiting.mean(), ref_sim.stats.waiting.mean());
        assert_eq!(new_sim.passes, ref_sim.passes);
        // Per-VR delivery content and order must match flit for flit.
        for vr in 0..n_vrs {
            let a: Vec<u64> = new_sim.vrs[vr].delivered.iter().map(|f| f.id).collect();
            let b: Vec<u64> = ref_sim.vrs[vr].delivered.iter().map(|f| f.id).collect();
            assert_eq!(a, b, "VR{vr} delivery order diverged");
            assert_eq!(new_sim.vrs[vr].rejected, ref_sim.vrs[vr].rejected);
        }
    });
}

#[test]
fn partitioned_gate_matches_single_lock_and_fixpoint_on_hop_traces() {
    // The lock-partitioning invariant: replaying one seeded trace of
    // serving hops (the atomic send-drain-collect unit the engines use)
    // through the single-lock gate (`&Mutex<NocSim>`, the pre-partition
    // worker gate), the per-column [`PartitionedNoc`], and a per-hop
    // replica on the fixpoint oracle yields identical per-hop cycle
    // counts, byte-identical delivered payloads (which pins per-VR
    // delivery order), and matching final statistics — counts and extrema
    // exactly, aggregate means to FP-merge-order tolerance. Random column
    // counts sweep the partition configs (1 column = degenerate single
    // cell, n columns = one router per cell).
    use fpga_mt::coordinator::shard::CoreGate;
    use fpga_mt::noc::{segment_message, PartitionedNoc, FLIT_PAYLOAD_BYTES};
    use std::sync::Mutex;

    forall("partitioned gate equivalence", 48, |rng| {
        let n = 4 + rng.below(9) as usize;
        let cols = 1 + rng.below(n as u64) as usize;
        let topo = Topology::multi_column(n, cols);
        let n_vrs = topo.n_vrs();
        let n_vis = 1 + rng.below(4) as u16;
        let mut single = NocSim::new(topo.clone());
        let mut oracle = FixpointSim::new(topo.clone());
        let mut part_src = NocSim::new(topo.clone());
        for vr in 0..n_vrs {
            let vi = rng.below(n_vis as u64) as u16;
            single.assign_vr(vr, vi);
            oracle.assign_vr(vr, vi);
            part_src.assign_vr(vr, vi);
        }
        // Wire the router-0 VR pair directly half the time, so traces
        // cover the direct-link fast path as well as routed flits.
        if rng.chance(0.5) {
            single.wire_direct(0, 1).unwrap();
            oracle.wire_direct(0, 1).unwrap();
            part_src.wire_direct(0, 1).unwrap();
        }
        let single = Mutex::new(single);
        let part = PartitionedNoc::from_sim(part_src);
        for _ in 0..rng.range_u64(5, 40) {
            let src = rng.index(n_vrs);
            let dst = rng.index(n_vrs);
            if dst == src {
                continue;
            }
            // Sometimes a foreign VI: the hop must reject identically.
            let vi = rng.below(n_vis as u64) as u16;
            let bytes = Payload::from(vec![rng.below(256) as u8; 1 + rng.below(96) as usize]);

            let mut gate: &Mutex<NocSim> = &single;
            let (sc, sb) = gate.stream(vi, src, dst, &bytes).unwrap();
            let (pc, pb) = part.stream(vi, src, dst, &bytes).unwrap();

            // Per-hop replica on the fixpoint oracle, mirroring
            // `stream_hop` + `collect_delivered` flit for flit.
            let header = oracle.header_for(vi, dst);
            let start = oracle.cycle();
            let direct = oracle.has_direct(src, dst);
            for f in segment_message(header, bytes.clone(), FLIT_PAYLOAD_BYTES, 0) {
                if direct {
                    oracle.send_direct(src, header, f.payload, f.seq);
                } else {
                    oracle.send(src, header, f.payload, f.seq);
                }
            }
            assert!(oracle.drain(1_000_000), "oracle failed to drain");
            let oc = oracle.cycle() - start;
            let mut ob = Vec::new();
            while let Some(f) = oracle.vrs[dst].delivered.pop_front() {
                ob.extend_from_slice(&f.payload);
            }

            assert_eq!(pc, sc, "hop {src}->{dst}: partitioned cycles diverged");
            assert_eq!(pb, sb, "hop {src}->{dst}: partitioned bytes diverged");
            assert_eq!(oc, sc, "hop {src}->{dst}: oracle cycles diverged");
            assert_eq!(ob, sb, "hop {src}->{dst}: oracle bytes diverged");
        }
        let s = single.into_inner().unwrap();
        let p = part.stats();
        assert_eq!(p.delivered, s.stats.delivered);
        assert_eq!(p.rejected, s.stats.rejected);
        assert_eq!(p.direct_delivered, s.stats.direct_delivered);
        assert_eq!(p.latency.count(), s.stats.latency.count());
        assert_eq!(p.latency.max(), s.stats.latency.max());
        assert_eq!(p.waiting.max(), s.stats.waiting.max());
        if p.latency.count() > 0 {
            // Merged per-column means may differ from the single
            // accumulator by FP merge order only.
            assert!((p.latency.mean() - s.stats.latency.mean()).abs() < 1e-9);
            assert!((p.waiting.mean() - s.stats.waiting.mean()).abs() < 1e-9);
        }
    });
}

#[test]
fn saturated_network_still_conserves_and_drains() {
    // Failure injection: overload far beyond capacity, then stop injecting.
    let topo = Topology::single_column(4);
    let n_vrs = topo.n_vrs();
    let mut sim = NocSim::new(topo);
    for vr in 0..n_vrs {
        sim.assign_vr(vr, 1);
    }
    let mut rng = Rng::new(99);
    let mut sent = 0u64;
    for _ in 0..2000 {
        for src in 0..n_vrs {
            let dst = rng.index(n_vrs);
            if dst != src {
                let h = sim.header_for(1, dst);
                sim.send(src, h, Payload::empty(), 0);
                sent += 1;
            }
        }
        sim.step();
    }
    assert!(sim.drain(1_000_000), "saturated network must drain once injection stops");
    assert_eq!(sim.stats.delivered + sim.stats.rejected, sent);
}

#[test]
fn lifecycle_ops_never_double_own_or_leak_wiring() {
    // Random streams of the full lifecycle API (create/allocate/program/
    // grow/release) applied via `Hypervisor::apply`. After every op:
    // - each non-free VR appears in exactly one VI's held list;
    // - the NoC access monitor mirrors hypervisor ownership;
    // - every wired direct link has both endpoints held (never a free VR);
    // - free VRs carry no footprint and no committed pblock resources;
    // - per-VR epochs never decrease.
    let designs = ["huffman", "fft", "fpu", "aes", "canny", "fir"];
    forall("lifecycle ownership/wiring invariants", 32, |rng| {
        let device = Device::vu9p();
        let (topo, fp) = placer::case_study_floorplan(&device).unwrap();
        let mut sim = NocSim::new(topo.clone());
        let mut hv = Hypervisor::new(topo, fp, Policy::AdjacentFirst);
        let vis: Vec<u16> = (0..3).map(|i| hv.create_vi(&format!("t{i}"))).collect();
        let mut last_epochs = vec![0u64; hv.vrs.len()];
        for _ in 0..rng.range_u64(10, 80) {
            let vi = vis[rng.index(vis.len())];
            let design = designs[rng.index(designs.len())].to_string();
            let held: Vec<usize> = hv.vis[&vi].vrs.clone();
            let op = match rng.below(4) {
                0 => LifecycleOp::Allocate { vi },
                1 => {
                    let Some(&vr) = held.first() else { continue };
                    LifecycleOp::Program { vi, vr, design, dest: None }
                }
                2 => {
                    let stream_src = held
                        .iter()
                        .copied()
                        .find(|&v| matches!(hv.vrs[v].status, VrStatus::Programmed { .. }));
                    LifecycleOp::Grow { vi, stream_src, design }
                }
                _ => {
                    if held.is_empty() {
                        continue;
                    }
                    LifecycleOp::Release { vi, vr: held[rng.index(held.len())] }
                }
            };
            let _ = hv.apply(&op, &design_footprint, &mut sim);

            // Exactly-one-owner invariant, mirrored into the NoC monitor.
            let mut owners = vec![0u32; hv.vrs.len()];
            for v in &vis {
                for &vr in &hv.vis[v].vrs {
                    owners[vr] += 1;
                }
            }
            for (vr, &count) in owners.iter().enumerate() {
                let allocated = hv.vrs[vr].status != VrStatus::Free;
                assert_eq!(count, u32::from(allocated), "VR{vr} ownership corrupt");
                assert_eq!(sim.vrs[vr].owner_vi.is_some(), allocated, "VR{vr} monitor");
                if !allocated {
                    assert!(hv.vrs[vr].footprint.is_zero(), "free VR{vr} keeps a footprint");
                    let pb = hv.floorplan.vr_pb[vr];
                    assert!(
                        hv.floorplan.pblocks.get(pb).used.is_zero(),
                        "free VR{vr} keeps committed pblock resources"
                    );
                }
                assert!(hv.vrs[vr].epoch >= last_epochs[vr], "VR{vr} epoch went backwards");
                last_epochs[vr] = hv.vrs[vr].epoch;
            }
            // Direct links only ever connect held regions.
            for (src, dst) in sim.direct_links() {
                assert_ne!(hv.vrs[src].status, VrStatus::Free, "link from free VR{src}");
                assert_ne!(hv.vrs[dst].status, VrStatus::Free, "link into free VR{dst}");
            }
        }
    });
}

#[test]
fn hostile_interleavings_never_corrupt_state_or_revive_tickets() {
    // Red-team satellite: random interleavings of legal lifecycle churn
    // (two cooperative tenants) and hostile ops from a third VI that was
    // admitted but owns nothing. After every op:
    // - every hostile op is refused AND leaves per-VR (status, epoch)
    //   state untouched — a refusal must be side-effect free;
    // - each non-free VR appears in exactly one VI's held list (the
    //   hostile VI's list stays empty forever);
    // - every wired direct link has both endpoints held;
    // - a captured (vi, vr, epoch) admission ticket that has gone stale
    //   once never validates again, no matter how ownership churns
    //   afterwards (epochs are monotonic and bump on every transition).
    let designs = ["huffman", "fft", "fpu", "aes", "canny", "fir"];
    forall("hostile-op interleavings", 32, |rng| {
        let device = Device::vu9p();
        let (topo, fp) = placer::case_study_floorplan(&device).unwrap();
        let mut sim = NocSim::new(topo.clone());
        let mut hv = Hypervisor::new(topo, fp, Policy::AdjacentFirst);
        let vis: Vec<u16> = (0..2).map(|i| hv.create_vi(&format!("t{i}"))).collect();
        let hostile = hv.create_vi("hostile");
        // Captured admission tickets: (vi, vr, epoch, went_stale).
        let mut tickets: Vec<(u16, usize, u64, bool)> = Vec::new();
        for _ in 0..rng.range_u64(20, 100) {
            let design = designs[rng.index(designs.len())].to_string();
            if rng.chance(0.45) {
                // --- hostile op: illegal by construction (the hostile VI
                // holds nothing, so any region it names is foreign/free) ---
                let foreign: Vec<usize> = (0..hv.vrs.len())
                    .filter(|&vr| hv.vrs[vr].status != VrStatus::Free)
                    .collect();
                let op = match rng.below(4) {
                    0 => {
                        // Squat on any region (held by another VI, or free
                        // and never allocated to the squatter).
                        let vr = rng.index(hv.vrs.len());
                        LifecycleOp::Program { vi: hostile, vr, design, dest: None }
                    }
                    1 => {
                        let Some(&src) = foreign.first() else { continue };
                        LifecycleOp::Wire {
                            vi: hostile,
                            src,
                            dst: (src + 1) % hv.vrs.len(),
                        }
                    }
                    2 => {
                        let Some(&vr) = foreign.last() else { continue };
                        LifecycleOp::Release { vi: hostile, vr }
                    }
                    _ => {
                        let src = foreign
                            .iter()
                            .copied()
                            .find(|&v| matches!(hv.vrs[v].status, VrStatus::Programmed { .. }));
                        let Some(src) = src else { continue };
                        LifecycleOp::Grow { vi: hostile, stream_src: Some(src), design }
                    }
                };
                let before: Vec<(VrStatus, u64)> =
                    hv.vrs.iter().map(|v| (v.status.clone(), v.epoch)).collect();
                assert!(
                    hv.apply(&op, &design_footprint, &mut sim).is_err(),
                    "hostile op must be refused: {op:?}"
                );
                let after: Vec<(VrStatus, u64)> =
                    hv.vrs.iter().map(|v| (v.status.clone(), v.epoch)).collect();
                assert_eq!(before, after, "refused hostile op mutated region state: {op:?}");
            } else {
                // --- legal churn from a cooperative tenant ---
                let vi = vis[rng.index(vis.len())];
                let held: Vec<usize> = hv.vis[&vi].vrs.clone();
                let op = match rng.below(4) {
                    0 => LifecycleOp::Allocate { vi },
                    1 => {
                        let Some(&vr) = held.first() else { continue };
                        LifecycleOp::Program { vi, vr, design, dest: None }
                    }
                    2 => {
                        let stream_src = held
                            .iter()
                            .copied()
                            .find(|&v| matches!(hv.vrs[v].status, VrStatus::Programmed { .. }));
                        LifecycleOp::Grow { vi, stream_src, design }
                    }
                    _ => {
                        if held.is_empty() {
                            continue;
                        }
                        LifecycleOp::Release { vi, vr: held[rng.index(held.len())] }
                    }
                };
                // Legal churn may still fail (pool exhaustion); a success
                // that programmed a region mints a fresh admission ticket.
                if let Ok((outcome, _)) = hv.apply(&op, &design_footprint, &mut sim) {
                    let programmed = match (&op, outcome) {
                        (LifecycleOp::Program { vr, .. }, _) => Some(*vr),
                        (LifecycleOp::Grow { .. }, LifecycleOutcome::Vr(vr)) => Some(vr),
                        _ => None,
                    };
                    if let Some(vr) = programmed {
                        tickets.push((vi, vr, hv.vrs[vr].epoch, false));
                    }
                }
            }

            // Exactly-one-owner across all three VIs; hostile owns nothing.
            assert!(hv.vis[&hostile].vrs.is_empty(), "hostile VI acquired a region");
            let mut owners = vec![0u32; hv.vrs.len()];
            for v in vis.iter().chain(std::iter::once(&hostile)) {
                for &vr in &hv.vis[v].vrs {
                    owners[vr] += 1;
                }
            }
            for (vr, &count) in owners.iter().enumerate() {
                let allocated = hv.vrs[vr].status != VrStatus::Free;
                assert_eq!(count, u32::from(allocated), "VR{vr} ownership corrupt");
                assert_eq!(sim.vrs[vr].owner_vi.is_some(), allocated, "VR{vr} monitor");
            }
            // No dangling stream wiring.
            for (src, dst) in sim.direct_links() {
                assert_ne!(hv.vrs[src].status, VrStatus::Free, "link from free VR{src}");
                assert_ne!(hv.vrs[dst].status, VrStatus::Free, "link into free VR{dst}");
            }
            // Staleness is permanent: once a ticket stops validating, it
            // never validates again.
            for t in &mut tickets {
                let valid = hv.vrs[t.1].epoch == t.2
                    && matches!(&hv.vrs[t.1].status,
                        VrStatus::Programmed { vi: o, .. } if *o == t.0);
                if t.3 {
                    assert!(!valid, "stale ticket for VR{} revived", t.1);
                } else if !valid {
                    t.3 = true;
                }
            }
        }
    });
}

#[test]
fn adjacent_first_grows_adjacent_whenever_a_neighbor_is_free() {
    forall("adjacent-first adjacency", 48, |rng| {
        let device = Device::vu9p();
        let (topo, fp) = placer::case_study_floorplan(&device).unwrap();
        let mut sim = NocSim::new(topo.clone());
        let mut hv = Hypervisor::new(topo, fp, Policy::AdjacentFirst);
        // Random pre-occupancy by another tenant.
        let other = hv.create_vi("other");
        for _ in 0..rng.below(4) {
            let _ = hv.allocate_vr(other, &mut sim);
        }
        let vi = hv.create_vi("grower");
        let Ok(first) = hv.allocate_vr(vi, &mut sim) else { return };
        // Does any free VR adjacent to the tenant's region exist?
        let neighbor_free = (0..hv.vrs.len())
            .any(|v| hv.vrs[v].status == VrStatus::Free && hv.topo.vrs_adjacent(first, v));
        match hv.allocate_vr(vi, &mut sim) {
            Ok(second) => {
                if neighbor_free {
                    assert!(
                        hv.topo.vrs_adjacent(first, second),
                        "free neighbor existed but got VR{second} (first VR{first})"
                    );
                }
            }
            Err(_) => assert_eq!(hv.free_vrs(), 0, "allocation may only fail when exhausted"),
        }
    });
}

#[test]
fn release_returns_vr_to_pool_with_links_unwired() {
    forall("release unwires and frees", 48, |rng| {
        let device = Device::vu9p();
        let (topo, fp) = placer::case_study_floorplan(&device).unwrap();
        let mut sim = NocSim::new(topo.clone());
        let mut hv = Hypervisor::new(topo, fp, Policy::AdjacentFirst);
        let vi = hv.create_vi("t");
        let src = hv.allocate_vr(vi, &mut sim).unwrap();
        hv.apply(
            &LifecycleOp::Program { vi, vr: src, design: "fpu".into(), dest: None },
            &design_footprint,
            &mut sim,
        )
        .unwrap();
        let (outcome, _) = hv
            .apply(
                &LifecycleOp::Grow { vi, stream_src: Some(src), design: "aes".into() },
                &design_footprint,
                &mut sim,
            )
            .unwrap();
        let LifecycleOutcome::Vr(dst) = outcome else { panic!("grow returns Vr") };
        assert!(sim.has_direct(src, dst));
        // Release one of the two endpoints at random: either way, no link
        // may survive, the region is free, and it is re-allocatable.
        let victim = if rng.chance(0.5) { src } else { dst };
        hv.apply(&LifecycleOp::Release { vi, vr: victim }, &design_footprint, &mut sim).unwrap();
        assert_eq!(hv.vrs[victim].status, VrStatus::Free);
        assert!(sim.vrs[victim].owner_vi.is_none());
        assert!(
            sim.direct_links().iter().all(|&(s, d)| s != victim && d != victim),
            "released VR{victim} still wired"
        );
        assert!(hv.vrs[victim].footprint.is_zero());
        let newcomer = hv.create_vi("n");
        let got = hv.allocate_vr(newcomer, &mut sim).unwrap();
        assert_eq!(got, victim, "AdjacentFirst hands a fresh tenant the lowest free VR");
    });
}

#[test]
fn journaled_control_streams_recover_at_every_prefix() {
    use fpga_mt::control::{control_trace, drive_control_trace, CrashPlan, MemLog};
    use fpga_mt::fleet::{FleetConfig, FleetScheduler, PlacePolicy};

    // The event-sourcing invariant, under random control streams: for a
    // journal of N entries, recovery from EVERY prefix 1..=N yields a
    // scheduler whose control digest — tenant registry, per-device
    // (status, epoch, footprint) vectors, route table — is byte-identical
    // to what the live controller held at that boundary. Cases and event
    // counts stay small: each case sweeps every prefix, so the work is
    // quadratic in the journal length.
    forall("journal prefix recovery", 6, |rng| {
        let devices = 1 + rng.index(2);
        let policy =
            if rng.chance(0.5) { PlacePolicy::Spread } else { PlacePolicy::BinPack };
        let mut sched =
            FleetScheduler::start(FleetConfig { policy, ..FleetConfig::new(devices) })
                .unwrap();
        sched.attach_journal(Box::new(MemLog::new()), true).unwrap();
        let events = 4 + rng.below(8) as usize;
        let trace = control_trace(devices, events, rng.range_u64(1, 1 << 48));
        drive_control_trace(&mut sched, &trace);

        let plan = CrashPlan::capture(&sched).unwrap();
        assert!(!plan.is_empty(), "a driven fleet must have journaled something");
        let checked = plan.assert_all_boundaries().unwrap();
        assert_eq!(checked, plan.len());

        // The final boundary doubles as the clean-restart case: the full
        // journal rebuilds the exact live state.
        let (recovered, report) = plan.recover_at(plan.len() - 1).unwrap();
        assert!(report.truncated.is_none(), "a live journal has no damaged tail");
        assert_eq!(recovered.serving_digest(), sched.serving_digest());
        let _ = recovered.stop();
        let _ = sched.stop();
    });
}
