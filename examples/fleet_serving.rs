//! Fleet walkthrough: one scheduler over two FPGAs — placement, the
//! shared cluster front-end, replica growth, and a live cross-device
//! migration, all over `&self` (admin never needs exclusive ownership of
//! the scheduler while serving runs).
//!
//! ```sh
//! cargo run --release --example fleet_serving
//! ```

use fpga_mt::api::{ServingBackend, TenancyBuilder};
use fpga_mt::fleet::{FleetCluster, FleetConfig, PlacePolicy};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    // Two independent devices (each its own floorplan, hypervisor, NoC,
    // and sharded engine) behind one shared front-end, spread placement.
    let fleet = FleetCluster::start(FleetConfig {
        policy: PlacePolicy::Spread,
        ..FleetConfig::new(2)
    })?;
    println!("booted a 2-device fleet ({} free VRs per device)\n", fleet.free_vrs(0)?);

    // Tenants arrive fleet-wide; placement spreads them. (`admit_tenant`
    // is the single-region shorthand for deploying a TenancyBuilder plan.)
    let video = fleet.admit_tenant("video-pipeline", "canny")?;
    let crypto = fleet.admit_tenant("crypto-batch", "aes")?;
    for (name, t) in [("video", video), ("crypto", crypto)] {
        let r = fleet.replicas(t)[0];
        println!("{name:>8} -> device {} (VI{}, VR{}, epoch {})", r.device, r.vi, r.vr, r.epoch);
    }
    fleet.advance_clocks(10_000.0)?; // deployment windows elapse

    // The front-end maps (tenant, request) -> device.
    let payload: Arc<[u8]> = (0..=255u8).collect::<Vec<u8>>().into();
    let resp = fleet.submit(video, Arc::clone(&payload))?;
    println!(
        "\nvideo request: device {} ran {:?} in {:.0} µs (ingress {:.1} µs)",
        resp.device,
        resp.response.path,
        resp.response.timing.total_us(800.0),
        resp.ingress_us
    );

    // The unified session surface works here too: a tenant-scoped
    // session pins the replica epochs and submits region-addressed.
    let session = fleet.session(fpga_mt::api::TenantRef::Tenant(video))?;
    let direct = session.submit(0, Arc::clone(&payload))?;
    println!("session request: path {:?} at epoch {}", direct.path, direct.epoch);

    // Demand grows: a second replica lands on the other device and the
    // router balances across both.
    let replica = fleet.grow_tenant(video)?;
    println!("\nvideo grew a replica on device {}", replica.device);
    let devices: Vec<usize> = (0..4)
        .map(|_| fleet.submit(video, Arc::clone(&payload)).map(|r| r.device))
        .collect::<anyhow::Result<_>>()?;
    println!("4 balanced requests landed on devices {devices:?}");

    // A multi-region streaming tenancy deploys through the same plan
    // machinery migration replays (allocate → program → wire, rollback
    // on failure).
    let chain = TenancyBuilder::new("fpu-chain").region("fpu").region("aes").stream(0, 1).plan()?;
    let chained = fleet.deploy_tenancy(&chain)?;
    fleet.advance_clocks(20_000.0)?;
    let resp = fleet.submit(chained, Arc::clone(&payload))?;
    println!("\nstreaming tenancy: path {:?} on device {}", resp.response.path, resp.device);

    // Live cross-device migration: crypto moves while serving.
    let from = fleet.replicas(crypto)[0].device;
    let to = 1 - from;
    let report = fleet.migrate_tenant(crypto, from, to)?;
    println!(
        "\nmigrated crypto {} -> {} ({} region); new epoch {}",
        report.from, report.to, report.regions, report.replicas[0].epoch
    );
    let resp = fleet.submit(crypto, Arc::clone(&payload))?;
    println!("post-migration request served by device {} at epoch {}", resp.device, resp.epoch);

    let migrations = fleet.migrations()?;
    let metrics = fleet.stop()?;
    println!(
        "\nfleet totals: {} requests, p50 {:.0} µs, p99 {:.0} µs, {migrations} migration(s)",
        metrics.requests,
        metrics.latency_percentile(50.0),
        metrics.latency_percentile(99.0),
    );
    Ok(())
}
