//! NoC design-space explorer: sweep topology flavor, router count, data
//! width and injection rate; print latency/waiting/area/Fmax for each
//! point. The tool a cloud provider would use to size the shell (§IV-A:
//! "the size and shape of each VR is left to the cloud provider's choice").
//!
//! Run: `cargo run --release --example noc_explorer [--cycles 40000]`

use fpga_mt::device::Device;
use fpga_mt::estimate::{router_fmax_mhz, router_resources, RouterConfig};
use fpga_mt::noc::{traffic, NocSim, Payload, Topology};
use fpga_mt::util::cli::Args;
use fpga_mt::util::table::{fnum, Table};
use fpga_mt::util::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let cycles = args.get_u64("cycles", 40_000);
    let device = Device::vu9p();

    // ---- single-router microbench across widths (Fig 10/12 combined) ----
    println!("== router design points ==");
    let mut t = Table::new(vec!["ports", "width", "LUT", "Fmax MHz", "lat@0.3", "lat@0.6"]);
    for ports in [3u32, 4] {
        for width in [32u32, 64, 128, 256] {
            let cfg = RouterConfig::bufferless(ports, width);
            let l3 = traffic::sweep_no_collision(0.3, cycles, 5).avg_latency;
            let l6 = traffic::sweep_no_collision(0.6, cycles, 5).avg_latency;
            t.row(vec![
                ports.to_string(),
                width.to_string(),
                router_resources(&cfg).lut.to_string(),
                fnum(router_fmax_mhz(&cfg, &device)),
                fnum(l3),
                fnum(l6),
            ]);
        }
    }
    t.print();

    // ---- network-level sweep: flavor x routers, uniform random traffic ----
    println!("\n== network sweep (uniform random traffic, rate 0.2/VR) ==");
    let mut t = Table::new(vec!["flavor", "routers", "VRs", "mean lat", "p-like max", "delivered"]);
    for (name, topo) in [
        ("single-column 3", Topology::single_column(3)),
        ("single-column 6", Topology::single_column(6)),
        ("double-column 6", Topology::double_column(6)),
        ("double-column 12", Topology::double_column(12)),
        ("multi-column 12x3", Topology::multi_column(12, 3)),
    ] {
        let n_vrs = topo.n_vrs();
        let n_routers = topo.n_routers();
        let mut sim = NocSim::new(topo);
        for vr in 0..n_vrs {
            sim.assign_vr(vr, 42);
        }
        let mut rng = Rng::new(7);
        for _ in 0..cycles / 4 {
            for src in 0..n_vrs {
                if rng.chance(0.2) {
                    let mut dst = rng.index(n_vrs);
                    if dst == src {
                        dst = (dst + 1) % n_vrs;
                    }
                    let h = sim.header_for(42, dst);
                    sim.send(src, h, Payload::empty(), 0);
                }
            }
            sim.step();
        }
        sim.drain(cycles);
        t.row(vec![
            name.to_string(),
            n_routers.to_string(),
            n_vrs.to_string(),
            fnum(sim.stats.latency.mean()),
            fnum(sim.stats.latency.max()),
            sim.stats.delivered.to_string(),
        ]);
    }
    t.print();
    Ok(())
}
