//! END-TO-END DRIVER (EXPERIMENTS.md §E2E): the paper's §V-D case study on
//! the full stack — 5 VIs, 6 VRs, 6 real accelerators (native runtime
//! backend), concurrent tenants through the threaded engine, IO-trip and
//! throughput measurements, and the Fig 13 placement map.
//!
//! Run: `cargo run --release --example multi_tenant_case_study`

use fpga_mt::accel::CASE_STUDY;
use fpga_mt::api::{ServingBackend, TenantRef};
use fpga_mt::cloud::{fig14_io_trips, IoConfig, Link, Scheme};
use fpga_mt::coordinator::{ShardedEngine, System};
use fpga_mt::device::Device;
use fpga_mt::placer;
use fpga_mt::util::table::{fnum, Table};

fn main() -> anyhow::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());

    // ---- Fig 13: placement of the 6 jobs ----
    let device = Device::vu9p();
    let (_, fp) = placer::case_study_floorplan(&device)?;
    let labels: Vec<(usize, String)> =
        CASE_STUDY.iter().map(|a| (a.vr, format!("{} (VI{})", a.display, a.vi))).collect();
    println!("{}", placer::ascii::render(&device, &fp, &labels));
    println!(
        "NoC share: {:.2}% of CLBs; NoC+jobs envelope: {:.2}%\n",
        fp.noc_clb_fraction(&device) * 100.0,
        fp.total_clb_fraction(&device) * 100.0
    );

    // ---- concurrent multi-tenant serving (real compute) ----
    // Space-shared: the sharded engine runs every VR's compute on its own
    // worker; requests to disjoint VRs never queue behind each other.
    // Every client goes through the unified session surface: a session
    // per tenant, pinned to the tenancy's lifecycle epochs at open.
    let dir2 = dir.clone();
    let engine = ShardedEngine::start(move || System::case_study(&dir2))?;
    let mut joins = Vec::new();
    let rounds = 12;
    for spec in CASE_STUDY.iter() {
        let session = engine.session(TenantRef::Vi(spec.vi))?;
        let region = session.region_of_vr(spec.vr).expect("case-study region");
        let name = spec.name;
        joins.push(std::thread::spawn(move || {
            let payload: std::sync::Arc<[u8]> =
                (0..256u32).map(|i| (i * 31 % 256) as u8).collect::<Vec<u8>>().into();
            let mut compute_us = 0.0;
            let mut io_us = 0.0;
            let t0 = std::time::Instant::now();
            for _ in 0..rounds {
                let resp = session.submit(region, payload.clone()).expect(name);
                compute_us += resp.timing.compute_us;
                io_us += resp.timing.io_us;
            }
            (name, io_us / rounds as f64, compute_us / rounds as f64, t0.elapsed())
        }));
    }
    let mut t = Table::new(vec!["accel", "mean io µs (model)", "mean compute µs (real)", "wall ms"]);
    for j in joins {
        let (name, io, comp, wall) = j.join().unwrap();
        t.row(vec![
            name.to_string(),
            fnum(io),
            fnum(comp),
            fnum(wall.as_secs_f64() * 1e3),
        ]);
    }
    let metrics = engine.shutdown();
    t.print();
    println!(
        "\nengine: {} requests, mean total {:.1} µs (model), ingress {:.2} Gb/s (model)\n",
        metrics.requests,
        metrics.total_us.mean(),
        metrics.throughput_gbps()
    );

    // ---- Fig 14: IO trip multi-tenant vs directIO ----
    let accels: Vec<(&str, u32)> =
        CASE_STUDY.iter().map(|a| (a.display, (a.vr / 2 + 1) as u32)).collect();
    let rows = fig14_io_trips(&accels, 4000, &IoConfig::default(), 7);
    let mut t = Table::new(vec!["accelerator", "directIO µs", "multi-tenant µs"]);
    for r in &rows {
        t.row(vec![r.accel.clone(), fnum(r.direct_us), fnum(r.multi_us)]);
    }
    t.print();
    println!(
        "-> 6 workloads share one device (6x utilization) for ~{:.1} µs extra per trip\n",
        rows.iter().map(|r| r.multi_us - r.direct_us).sum::<f64>() / rows.len() as f64
    );

    // ---- Fig 15: streaming throughput ----
    let cfg = IoConfig::default();
    let mut t = Table::new(vec!["payload KB", "local Gb/s", "remote Gb/s"]);
    for kb in [100u64, 200, 300, 400] {
        t.row(vec![
            kb.to_string(),
            fnum(cfg.stream_gbps(Scheme::MultiTenant, kb * 1024, &Link::local())),
            fnum(cfg.stream_gbps(Scheme::MultiTenant, kb * 1024, &Link::testbed_ethernet())),
        ]);
    }
    t.print();
    Ok(())
}
