//! Quickstart: bring up a small multi-tenant deployment, send packets over
//! the NoC, and run one real accelerator through the runtime.
//!
//! Run: `cargo run --release --example quickstart`

use fpga_mt::device::Device;
use fpga_mt::hypervisor::{Hypervisor, Policy};
use fpga_mt::noc::{NocSim, Topology};
use fpga_mt::placer;
use fpga_mt::runtime::{Runtime, Tensor};

fn main() -> anyhow::Result<()> {
    // 1. A device and a 2-router / 4-VR single-column NoC deployment.
    let device = Device::vu9p();
    let topo = Topology::single_column(2);
    let fp = placer::place(&device, &topo, 19, 59)?;
    let mut noc = NocSim::new(topo.clone());
    let mut hv = Hypervisor::new(topo, fp, Policy::AdjacentFirst);

    // 2. Two tenants, one VR each (the §III-B flow).
    let alice = hv.create_vi("alice");
    let bob = hv.create_vi("bob");
    let vr_a = hv.allocate_vr(alice, &mut noc)?;
    let vr_b = hv.allocate_vr(bob, &mut noc)?;
    let t_us = hv.program_vr(alice, vr_a, "fir", None)?;
    hv.program_vr(bob, vr_b, "fft", None)?;
    println!("alice got VR{vr_a} (programmed in {t_us:.0} µs), bob got VR{vr_b}");

    // 3. Packets: alice's VR sends to bob's? No — the access monitor drops
    // cross-tenant traffic. Watch it happen.
    let foreign = noc.header_for(alice, vr_b); // claims alice's VI, targets bob's VR
    noc.send(vr_a, foreign, vec![1, 2, 3], 0);
    noc.drain(64);
    println!(
        "cross-tenant packet: delivered={} rejected={}",
        noc.stats.delivered, noc.stats.rejected
    );

    // 4. Real compute: run alice's FIR accelerator through the runtime.
    let rt = Runtime::load_dir("artifacts")?;
    let signal: Vec<f32> = (0..1024).map(|i| (i as f32 * 0.1).sin()).collect();
    let taps = vec![1.0 / 8.0; 8];
    let mut padded_taps = taps.clone();
    padded_taps.resize(16, 0.0);
    let out = rt.execute("fir", &[Tensor::vec1(signal), Tensor::vec1(padded_taps)])?;
    println!("fir output: first 4 = {:?}", &out[0].data[..4]);

    // 5. Elastic growth: alice asks for a second VR, adjacent if possible.
    let vr_a2 = hv.grow(alice, Some(vr_a), &mut noc)?;
    println!(
        "alice grew to VR{vr_a2}; adjacent={} (direct-link capable)",
        hv.topo.vrs_adjacent(vr_a, vr_a2)
    );
    println!("free VRs remaining: {}", hv.free_vrs());
    Ok(())
}
