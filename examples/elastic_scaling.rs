//! The paper's elasticity story (§V-D1), end to end: VI3 deploys an FPU,
//! later needs encryption, requests an additional VR at run-time, and the
//! FPU's results stream into AES over the on-chip direct link — with real
//! compute at both ends and a comparison against the middleware-copy
//! alternative the paper argues against.
//!
//! Run: `cargo run --release --example elastic_scaling`

use fpga_mt::cloud::IoConfig;
use fpga_mt::coordinator::System;
use fpga_mt::estimate::link_bandwidth_gbps;
use fpga_mt::hypervisor::Event;

fn main() -> anyhow::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let mut sys = System::case_study(&dir)?;

    println!("hypervisor event log (deployment):");
    for e in &sys.hv.events {
        println!("  {e:?}");
    }

    // VI3 drives its FPU; results stream on-chip into its AES region.
    let payload: Vec<u8> = (0..64).map(|i| (i * 5 + 3) as u8).collect();
    let resp = sys.submit(3, 2, &payload)?;
    println!("\nrequest path: {:?}", resp.path);
    println!("NoC streaming cycles: {}", resp.timing.noc_cycles);

    // On-chip vs middleware copy (the paper's 25.6 Gbps vs ~50 µs story).
    let stream_bytes = 4096 * 4; // FPU output tensor
    let noc_us = resp.timing.noc_cycles as f64 / sys.io_cfg.noc_clock_mhz;
    let middleware_us = 2.0 * IoConfig::default().base_os_us; // copy out + copy in
    println!("\nFPU -> AES transfer of {stream_bytes} bytes:");
    println!("  on-chip NoC:        {noc_us:.2} µs ({} Gbps link)", link_bandwidth_gbps(32, 800.0));
    println!("  middleware copy:    ~{middleware_us:.0} µs (two host IO trips)");
    println!("  speedup:            {:.0}x", middleware_us / noc_us.max(1e-9));

    // Elastic release: VI3 shrinks back, the VR returns to the pool.
    let before = sys.hv.free_vrs();
    sys.hv.release_vr(3, 3, &mut sys.core.noc)?;
    println!("\nreleased VR4: free VRs {} -> {}", before, sys.hv.free_vrs());
    for e in sys.hv.events.iter().rev().take(1) {
        println!("  {e:?}");
    }
    assert!(sys
        .hv
        .events
        .iter()
        .any(|e| matches!(e, Event::VrReleased { vi: 3, .. })));
    Ok(())
}
