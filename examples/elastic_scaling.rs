//! The paper's elasticity story (§V-D1), end to end: VI3 deploys an FPU,
//! later needs encryption, requests an additional VR at run-time, and the
//! FPU's results stream into AES over the on-chip direct link — with real
//! compute at both ends and a comparison against the middleware-copy
//! alternative the paper argues against. Serving goes through the
//! unified session surface; the release shows the session going stale.
//!
//! Run: `cargo run --release --example elastic_scaling`

use fpga_mt::api::{SerialBackend, ServingBackend, TenantRef};
use fpga_mt::cloud::IoConfig;
use fpga_mt::coordinator::System;
use fpga_mt::estimate::link_bandwidth_gbps;
use fpga_mt::hypervisor::{Event, LifecycleOp};

fn main() -> anyhow::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let backend = SerialBackend::new(System::case_study(&dir)?);

    println!("hypervisor event log (deployment):");
    backend.with_system(|sys| {
        for e in &sys.hv.events {
            println!("  {e:?}");
        }
    });

    // VI3's session: its FPU region (streaming into AES) and its AES
    // region, epochs pinned at open.
    let session = backend.session(TenantRef::Vi(3))?;
    let fpu = session.region_of_vr(2).expect("VI3's FPU region");
    let payload: Vec<u8> = (0..64).map(|i| (i * 5 + 3) as u8).collect();
    let resp = session.submit(fpu, payload)?;
    println!("\nrequest path: {:?}", resp.path);
    println!("NoC streaming cycles: {}", resp.timing.noc_cycles);

    // On-chip vs middleware copy (the paper's 25.6 Gbps vs ~50 µs story).
    let stream_bytes = 4096 * 4; // FPU output tensor
    let noc_clock_mhz = backend.with_system(|sys| sys.io_cfg.noc_clock_mhz);
    let noc_us = resp.timing.noc_cycles as f64 / noc_clock_mhz;
    let middleware_us = 2.0 * IoConfig::default().base_os_us; // copy out + copy in
    println!("\nFPU -> AES transfer of {stream_bytes} bytes:");
    println!("  on-chip NoC:        {noc_us:.2} µs ({} Gbps link)", link_bandwidth_gbps(32, 800.0));
    println!("  middleware copy:    ~{middleware_us:.0} µs (two host IO trips)");
    println!("  speedup:            {:.0}x", middleware_us / noc_us.max(1e-9));

    // Elastic release: VI3 shrinks back, the VR returns to the pool —
    // and the session that pinned the old tenancy goes stale instead of
    // silently serving a different shape.
    let (before, after) = backend.with_system(|sys| {
        let before = sys.hv.free_vrs();
        sys.core.timing.advance_clock(20_000.0); // boot windows are closed anyway
        sys.lifecycle(&LifecycleOp::Release { vi: 3, vr: 3 })?;
        anyhow::Ok((before, sys.hv.free_vrs()))
    })?;
    println!("\nreleased VR4: free VRs {before} -> {after}");
    let aes = session.region_of_vr(3).expect("the stale session still lists VR3");
    let stale = session.submit(aes, vec![1u8; 16]).unwrap_err();
    println!("stale session refused as expected: {stale}");
    backend.with_system(|sys| {
        for e in sys.hv.events.iter().rev().take(1) {
            println!("  {e:?}");
        }
        assert!(sys.hv.events.iter().any(|e| matches!(e, Event::VrReleased { vi: 3, .. })));
    });
    Ok(())
}
