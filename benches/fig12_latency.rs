//! Fig 12: average latency (a) and waiting time (b) per injection rate on
//! the 3-port router, with and without collision — cycle-accurate sim.

use fpga_mt::bench_support::{bench, check, header};
use fpga_mt::noc::traffic::{fig12_sweep, sweep_no_collision};
use fpga_mt::util::table::{fnum, Table};

fn main() {
    header(
        "Fig 12 — latency & waiting vs injection rate (3-port router)",
        "@0.6 no-collision: latency 3 cyc, waiting 1.66 cyc; collision waiting ~2x (stable band)",
    );
    let cycles = 60_000;
    let rates = [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9];
    let (nc, coll) = fig12_sweep(&rates, cycles, 42);
    let mut t = Table::new(vec!["rate", "lat nc", "wait nc", "lat coll", "wait coll"]);
    for (a, b) in nc.iter().zip(&coll) {
        let sat = if b.injection_rate >= 0.5 { " (sat)" } else { "" };
        t.row(vec![
            format!("{:.1}", a.injection_rate),
            fnum(a.avg_latency),
            fnum(a.avg_waiting),
            format!("{}{}", fnum(b.avg_latency), sat),
            format!("{}{}", fnum(b.avg_waiting), sat),
        ]);
    }
    t.print();

    let p06 = nc.iter().find(|p| (p.injection_rate - 0.6).abs() < 1e-9).unwrap();
    check("latency @0.6 ~ 3 cycles", (p06.avg_latency - 3.0).abs() < 0.5);
    check("waiting @0.6 ~ 1.66 cycles", (p06.avg_waiting - 1.66).abs() < 0.5);
    let ratios: Vec<f64> = nc
        .iter()
        .zip(&coll)
        .filter(|(a, _)| a.injection_rate >= 0.3 && a.injection_rate <= 0.45)
        .map(|(a, b)| b.avg_waiting / a.avg_waiting)
        .collect();
    let avg_ratio = ratios.iter().sum::<f64>() / ratios.len().max(1) as f64;
    println!("\ncollision/no-collision waiting ratio (stable band): {avg_ratio:.2}");
    check("collision waiting ~2x", (1.4..=3.5).contains(&avg_ratio));
    let monotone = nc.windows(2).all(|w| w[1].avg_waiting >= w[0].avg_waiting - 0.05);
    check("waiting grows with injection rate", monotone);

    bench("noc sim: 60k cycles @0.6 no-collision", 1, 10, || {
        std::hint::black_box(sweep_no_collision(0.6, cycles, 7));
    });
}
