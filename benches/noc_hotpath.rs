//! Perf bench for the L3 hot paths: NoC cycle engine throughput
//! (router-cycles/s) and end-to-end PJRT dispatch. This is the target of
//! EXPERIMENTS.md §Perf, not a paper figure.

use fpga_mt::bench_support::{bench, header};
use fpga_mt::noc::{NocSim, Topology};
use fpga_mt::runtime::{Runtime, Tensor};
use fpga_mt::util::Rng;

fn main() {
    header(
        "Perf — NoC cycle engine & PJRT dispatch hot paths",
        "engine target: >= 10M router-cycles/s; dispatch: PJRT execute dominates coordinator overhead",
    );

    // NoC engine: 12-router double column under uniform load.
    let topo = Topology::double_column(12);
    let n_vrs = topo.n_vrs();
    let cycles_per_iter = 20_000u64;
    let s = bench("noc engine: 12 routers, rate 0.3/VR, 20k cycles", 2, 10, || {
        let mut sim = NocSim::new(topo.clone());
        for vr in 0..n_vrs {
            sim.assign_vr(vr, 1);
        }
        let mut rng = Rng::new(3);
        for _ in 0..cycles_per_iter {
            for src in 0..n_vrs {
                if rng.chance(0.3) {
                    let mut dst = rng.index(n_vrs);
                    if dst == src {
                        dst = (dst + 1) % n_vrs;
                    }
                    let h = sim.header_for(1, dst);
                    sim.send(src, h, vec![], 0);
                }
            }
            sim.step();
        }
        std::hint::black_box(sim.stats.delivered);
    });
    let router_cycles = cycles_per_iter as f64 * topo.n_routers() as f64;
    println!(
        "-> {:.1}M router-cycles/s\n",
        router_cycles / s.mean() // cycles per µs = M cycles per s
    );

    // Idle engine (no traffic): pure stepping cost.
    bench("noc engine idle: 20k cycles", 2, 10, || {
        let mut sim = NocSim::new(topo.clone());
        for _ in 0..cycles_per_iter {
            sim.step();
        }
        std::hint::black_box(sim.cycle());
    });

    // PJRT dispatch, if artifacts exist.
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(dir).join("fir.hlo.txt").exists() {
        let rt = Runtime::load_dir(dir).unwrap();
        let x: Vec<f32> = (0..1024).map(|i| i as f32 * 0.01).collect();
        let h = vec![0.0625f32; 16];
        bench("pjrt execute: fir (1024, 16 taps)", 5, 50, || {
            std::hint::black_box(
                rt.execute("fir", &[Tensor::vec1(x.clone()), Tensor::vec1(h.clone())]).unwrap(),
            );
        });
        let a: Vec<f32> = (0..4096).map(|i| (i % 7) as f32).collect();
        bench("pjrt execute: fpu (4096 x3)", 5, 50, || {
            std::hint::black_box(
                rt.execute(
                    "fpu",
                    &[Tensor::vec1(a.clone()), Tensor::vec1(a.clone()), Tensor::vec1(a.clone())],
                )
                .unwrap(),
            );
        });
        let img: Vec<f32> = (0..128 * 128).map(|i| (i % 255) as f32).collect();
        bench("pjrt execute: canny (128x128)", 3, 20, || {
            std::hint::black_box(
                rt.execute("canny", &[Tensor::new(vec![128, 128], img.clone())]).unwrap(),
            );
        });
        let re: Vec<f32> = (0..2048).map(|i| (i % 17) as f32).collect();
        bench("pjrt execute: fft (8x256)", 3, 20, || {
            std::hint::black_box(
                rt.execute(
                    "fft",
                    &[Tensor::new(vec![8, 256], re.clone()), Tensor::new(vec![8, 256], re.clone())],
                )
                .unwrap(),
            );
        });
        let blocks: Vec<f32> = (0..256).map(|i| i as f32).collect();
        let rks = fpga_mt::accel::native::aes_key_expand(&fpga_mt::accel::DEMO_KEY);
        let rk_f: Vec<f32> = rks.iter().flatten().map(|&b| b as f32).collect();
        bench("pjrt execute: aes (16 blocks)", 3, 20, || {
            std::hint::black_box(
                rt.execute(
                    "aes",
                    &[
                        Tensor::new(vec![16, 16], blocks.clone()),
                        Tensor::new(vec![11, 16], rk_f.clone()),
                    ],
                )
                .unwrap(),
            );
        });
    } else {
        println!("(artifacts/ missing: skipping PJRT dispatch benches)");
    }
}
