//! Perf bench for the L3 hot paths: NoC cycle-engine throughput
//! (router-cycles/s) and end-to-end accelerator dispatch. This is the
//! target of EXPERIMENTS.md §Perf, not a paper figure.
//!
//! The NoC section is an A/B harness: the same workload runs on the
//! retained reference engine ([`FixpointSim`]) and the batched engine
//! ([`NocSim`]); the two must agree on every statistic **and** on the
//! fixpoint pass count (cycle-for-cycle identity), and the batched engine
//! must be measurably faster.
//!
//! A second A/B compares the serving gates: one `Mutex<NocSim>` over the
//! whole network vs the per-column [`PartitionedNoc`], with one thread
//! per column streaming intra-column hops. The hops must be cycle- and
//! byte-identical across the gates, and (non-smoke, multi-core) the
//! partitioned gate must win.

use fpga_mt::bench_support::{bench, check, finish, header, smoke_mode, speedup};
use fpga_mt::noc::{
    collect_delivered, lock_noc, stream_hop, FixpointSim, NocSim, NocStats, PartitionedNoc,
    Payload, Topology,
};
use fpga_mt::runtime::{Runtime, Tensor};
use fpga_mt::util::Rng;
use std::sync::Mutex;

/// Drive one engine through the standard uniform-load workload; both
/// engines expose the same send/step API so the closure bodies stay in
/// lockstep by construction.
fn drive_reference(topo: &Topology, cycles: u64, rate: f64, seed: u64) -> (NocStats, u64, u64) {
    let n_vrs = topo.n_vrs();
    let mut sim = FixpointSim::new(topo.clone());
    for vr in 0..n_vrs {
        sim.assign_vr(vr, 1);
    }
    let mut rng = Rng::new(seed);
    for _ in 0..cycles {
        for src in 0..n_vrs {
            if rng.chance(rate) {
                let mut dst = rng.index(n_vrs);
                if dst == src {
                    dst = (dst + 1) % n_vrs;
                }
                let h = sim.header_for(1, dst);
                sim.send(src, h, Payload::empty(), 0);
            }
        }
        sim.step();
    }
    sim.drain(cycles * 16);
    (sim.stats.clone(), sim.passes, sim.cycle())
}

fn drive_batched(topo: &Topology, cycles: u64, rate: f64, seed: u64) -> (NocStats, u64, u64) {
    let n_vrs = topo.n_vrs();
    let mut sim = NocSim::new(topo.clone());
    for vr in 0..n_vrs {
        sim.assign_vr(vr, 1);
    }
    let mut rng = Rng::new(seed);
    for _ in 0..cycles {
        for src in 0..n_vrs {
            if rng.chance(rate) {
                let mut dst = rng.index(n_vrs);
                if dst == src {
                    dst = (dst + 1) % n_vrs;
                }
                let h = sim.header_for(1, dst);
                sim.send(src, h, Payload::empty(), 0);
            }
        }
        sim.step();
    }
    sim.drain(cycles * 16);
    (sim.stats.clone(), sim.passes, sim.cycle())
}

fn main() {
    let smoke = smoke_mode();
    header(
        "Perf — NoC cycle engine & accelerator dispatch hot paths",
        "engine target: >= 10M router-cycles/s; batched engine must match the reference cycle-for-cycle",
    );
    // Smoke mode (CI): short workload, equivalence checks still enforced.
    let cycles: u64 = if smoke { 2_000 } else { 20_000 };
    let (warm, iters) = if smoke { (1, 2) } else { (2, 10) };

    // ---- A/B identity: batched engine vs retained reference engine ----
    let topo = Topology::double_column(12);
    let (ref_stats, ref_passes, ref_cycle) = drive_reference(&topo, cycles, 0.3, 3);
    let (new_stats, new_passes, new_cycle) = drive_batched(&topo, cycles, 0.3, 3);
    check(
        "delivered identical",
        ref_stats.delivered == new_stats.delivered,
    );
    check("rejected identical", ref_stats.rejected == new_stats.rejected);
    check(
        "latency distribution identical",
        ref_stats.latency.mean() == new_stats.latency.mean()
            && ref_stats.latency.max() == new_stats.latency.max()
            && ref_stats.latency.count() == new_stats.latency.count(),
    );
    check(
        "waiting distribution identical",
        ref_stats.waiting.mean() == new_stats.waiting.mean(),
    );
    check("fixpoint pass count identical", ref_passes == new_passes);
    check("drain cycle identical", ref_cycle == new_cycle);

    // ---- throughput: 12-router double column under uniform load ----
    let s_ref = bench("reference engine: 12 routers, rate 0.3/VR", warm, iters, || {
        std::hint::black_box(drive_reference(&topo, cycles, 0.3, 3));
    });
    let s_new = bench("batched engine:   12 routers, rate 0.3/VR", warm, iters, || {
        std::hint::black_box(drive_batched(&topo, cycles, 0.3, 3));
    });
    let router_cycles = cycles as f64 * topo.n_routers() as f64;
    println!(
        "-> reference {:.1}M router-cycles/s, batched {:.1}M router-cycles/s",
        router_cycles / s_ref.mean(), // cycles per µs = M cycles per s
        router_cycles / s_new.mean(),
    );
    let ratio = speedup("batched vs reference (loaded)", &s_ref, &s_new);
    if smoke {
        println!("(smoke mode: speedup gate skipped; timings too short to be stable)");
    } else {
        check("batched engine is faster under load", ratio > 1.0);
    }

    // Idle engine (no traffic): pure stepping cost.
    bench("batched engine idle", warm, iters, || {
        let mut sim = NocSim::new(topo.clone());
        for _ in 0..cycles {
            sim.step();
        }
        std::hint::black_box(sim.cycle());
    });

    // ---- lock partitioning: per-column cells vs one mutex ----
    // One thread per physical column streams routed intra-column hops.
    // Under the single lock every hop convoys on every other column's;
    // the partitioned gate serializes only within a column.
    let mtopo = Topology::multi_column(12, 4);
    let columns = 4usize;
    // Column c owns routers 3c..3c+2: hop router-(3c) east VR to
    // router-(3c+2) west VR — routed (not adjacent), never leaves c.
    let hop_of = |c: usize| (6 * c + 1, 6 * c + 4);
    let assigned = |topo: &Topology| {
        let mut sim = NocSim::new(topo.clone());
        for vr in 0..topo.n_vrs() {
            sim.assign_vr(vr, 1);
        }
        sim
    };
    let payload = Payload::from(vec![0xA5u8; 256]);

    // Equivalence first: each column's hop must be cycle- and
    // byte-identical across the two gates.
    {
        let mut whole = assigned(&mtopo);
        let part = PartitionedNoc::from_sim(assigned(&mtopo));
        let mut identical = true;
        for c in 0..columns {
            let (src, dst) = hop_of(c);
            let cycles = stream_hop(&mut whole, 1, src, dst, &payload).unwrap();
            let bytes = collect_delivered(&mut whole, dst);
            let (pcycles, pbytes) = part.stream(1, src, dst, &payload).unwrap();
            identical &= pcycles == cycles && pbytes == bytes;
        }
        check("partitioned gate cycle- and byte-identical per column", identical);
        let (ps, ws) = (part.stats(), whole.stats);
        check(
            "partitioned stats identical after the sweep",
            ps.delivered == ws.delivered
                && ps.rejected == ws.rejected
                && ps.latency.count() == ws.latency.count()
                && ps.latency.max() == ws.latency.max(),
        );
    }

    let hops_per_col: u64 = if smoke { 40 } else { 400 };
    let s_single = bench("single-lock gate: 4 columns contending", warm, iters, || {
        let shared = Mutex::new(assigned(&mtopo));
        std::thread::scope(|scope| {
            for c in 0..columns {
                let shared = &shared;
                let payload = &payload;
                scope.spawn(move || {
                    let (src, dst) = hop_of(c);
                    for _ in 0..hops_per_col {
                        let mut noc = lock_noc(shared);
                        stream_hop(&mut noc, 1, src, dst, payload).unwrap();
                        std::hint::black_box(collect_delivered(&mut noc, dst));
                    }
                });
            }
        });
    });
    let s_part = bench("partitioned gate:  4 columns in parallel", warm, iters, || {
        let part = PartitionedNoc::from_sim(assigned(&mtopo));
        std::thread::scope(|scope| {
            for c in 0..columns {
                let part = &part;
                let payload = &payload;
                scope.spawn(move || {
                    let (src, dst) = hop_of(c);
                    for _ in 0..hops_per_col {
                        std::hint::black_box(part.stream(1, src, dst, payload).unwrap());
                    }
                });
            }
        });
    });
    let part_ratio = speedup("partitioned vs single lock (4 columns)", &s_single, &s_part);
    if smoke {
        println!("(smoke mode: partitioning speedup gate skipped; may be core-limited)");
    } else {
        check("per-column partitioning beats the single lock", part_ratio > 1.0);
    }

    // ---- accelerator dispatch (native runtime backend) ----
    // Smoke mode stops here: the dispatch micro-benches carry no
    // assertions, and CI only gates on the A/B equivalence checks above.
    if smoke {
        finish();
    }
    let rt = Runtime::load_dir("artifacts").unwrap();
    let x: Vec<f32> = (0..1024).map(|i| i as f32 * 0.01).collect();
    let h = vec![0.0625f32; 16];
    bench("runtime execute: fir (1024, 16 taps)", 5, 50, || {
        std::hint::black_box(
            rt.execute("fir", &[Tensor::vec1(x.clone()), Tensor::vec1(h.clone())]).unwrap(),
        );
    });
    let a: Vec<f32> = (0..4096).map(|i| (i % 7) as f32).collect();
    bench("runtime execute: fpu (4096 x3)", 5, 50, || {
        std::hint::black_box(
            rt.execute(
                "fpu",
                &[Tensor::vec1(a.clone()), Tensor::vec1(a.clone()), Tensor::vec1(a.clone())],
            )
            .unwrap(),
        );
    });
    let img: Vec<f32> = (0..128 * 128).map(|i| (i % 255) as f32).collect();
    bench("runtime execute: canny (128x128)", 3, 20, || {
        std::hint::black_box(
            rt.execute("canny", &[Tensor::new(vec![128, 128], img.clone())]).unwrap(),
        );
    });
    let re: Vec<f32> = (0..2048).map(|i| (i % 17) as f32).collect();
    bench("runtime execute: fft (8x256)", 3, 20, || {
        std::hint::black_box(
            rt.execute(
                "fft",
                &[Tensor::new(vec![8, 256], re.clone()), Tensor::new(vec![8, 256], re.clone())],
            )
            .unwrap(),
        );
    });
    let blocks: Vec<f32> = (0..256).map(|i| i as f32).collect();
    let rks = fpga_mt::accel::native::aes_key_expand(&fpga_mt::accel::DEMO_KEY);
    let rk_f: Vec<f32> = rks.iter().flatten().map(|&b| b as f32).collect();
    bench("runtime execute: aes (16 blocks)", 3, 20, || {
        std::hint::black_box(
            rt.execute(
                "aes",
                &[
                    Tensor::new(vec![16, 16], blocks.clone()),
                    Tensor::new(vec![11, 16], rk_f.clone()),
                ],
            )
            .unwrap(),
        );
    });
    finish();
}
