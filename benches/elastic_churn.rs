//! Elastic tenant churn vs a static allocation — the paper's utilization
//! headline (§III-A / §V-D) measured on the live sharded engine.
//!
//! One seeded churn process (tenants arrive, deploy, grow, shrink,
//! depart, and drive traffic — `coordinator::churn`) is replayed twice:
//!
//! 1. **Elastic**: the engine applies every lifecycle op live — regions
//!    are reclaimed on departure and re-deployed to later arrivals
//!    (hot-add / hot-drain of worker shards, reconfiguration windows
//!    charged to admission).
//! 2. **Static**: the same demand, but the allocation is fixed at each
//!    tenant's first deployment — no growth, and no reclamation, so a
//!    departed tenant's region stays stranded and later arrivals that
//!    find the pool exhausted are turned away (their requests fail).
//!
//! Reported per run: mean *useful* utilization (programmed regions owned
//! by a still-active tenant / total regions, sampled at every request
//! instant of the demand trace), requests served, and requests/sec.
//! The elastic run must beat the static baseline on both utilization and
//! served requests — `--smoke` enforces the same checks at CI size and
//! exits non-zero on failure.
//!
//! This bench deliberately drives the raw `EngineHandle` envelope rather
//! than the session surface (`fpga_mt::api`): a churn trace interleaves
//! lifecycle ops with requests whose targets the ops keep invalidating,
//! and replaying it through epoch-pinned sessions would reopen a session
//! per event — the handle is the documented trace-replay surface.
//!
//! Request payloads are drawn from `workload::arrivals` (the open-loop
//! SLO bench's heavy-tailed size distribution), so churn and SLO benches
//! share one seeded source of truth for demand; the churn *event*
//! sequence itself is untouched.

use fpga_mt::bench_support::{check, finish, header, smoke_mode};
use fpga_mt::coordinator::churn::{self, ChurnConfig, ChurnEvent};
use fpga_mt::coordinator::design_footprint;
use fpga_mt::coordinator::{ShardedEngine, System};
use fpga_mt::device::Device;
use fpga_mt::hypervisor::{Hypervisor, LifecycleOp, LifecycleOutcome, Policy, VrStatus};
use fpga_mt::noc::NocSim;
use fpga_mt::placer::case_study_floorplan;
use fpga_mt::workload::arrivals::{payload_pool, PayloadDist};
use std::collections::{HashMap, HashSet};
use std::sync::Arc;
use std::time::Instant;

/// A shadow world: the same hypervisor/NoC state the engine holds,
/// mirrored outside it so the bench can sample utilization per event.
struct Shadow {
    hv: Hypervisor,
    noc: NocSim,
}

fn shadow() -> Shadow {
    let device = Device::vu9p();
    let (topo, fp) = case_study_floorplan(&device).expect("case-study floorplan");
    let noc = NocSim::new(topo.clone());
    Shadow { hv: Hypervisor::new(topo, fp, Policy::AdjacentFirst), noc }
}

/// Transform the elastic trace into its static-allocation counterpart,
/// aligned 1:1 with the original (dropped ops become `None`):
/// - `CreateVi` is kept (VI numbering must match the demand trace);
/// - each tenant keeps only its FIRST allocate+program, re-resolved
///   against a static shadow world (indices differ once reclamation is
///   off); tenants that find the pool exhausted are turned away;
/// - `Grow`/`Wire`/`Release` are dropped: a static allocation cannot
///   resize, and never returns regions to the pool;
/// - requests are redirected to the tenant's static region when it has
///   one, else left aimed at the elastic-world target (where they fail —
///   the turned-away tenant's traffic).
fn static_baseline(events: &[ChurnEvent]) -> Vec<Option<ChurnEvent>> {
    let mut world = shadow();
    let mut static_vr: HashMap<u16, usize> = HashMap::new();
    let mut programmed: HashSet<u16> = HashSet::new();
    let mut denied: HashSet<u16> = HashSet::new();
    events
        .iter()
        .map(|event| match event {
            ChurnEvent::Op(op) => match op {
                LifecycleOp::CreateVi { .. } => {
                    let _ = world.hv.apply(op, &design_footprint, &mut world.noc);
                    Some(event.clone())
                }
                LifecycleOp::Allocate { vi } => {
                    if static_vr.contains_key(vi) || denied.contains(vi) {
                        return None;
                    }
                    match world.hv.apply(op, &design_footprint, &mut world.noc) {
                        Ok((LifecycleOutcome::Vr(vr), _)) => {
                            static_vr.insert(*vi, vr);
                            Some(ChurnEvent::Op(op.clone()))
                        }
                        _ => {
                            denied.insert(*vi);
                            None
                        }
                    }
                }
                LifecycleOp::Program { vi, design, .. } => {
                    if programmed.contains(vi) {
                        return None;
                    }
                    let Some(&vr) = static_vr.get(vi) else { return None };
                    let op =
                        LifecycleOp::Program { vi: *vi, vr, design: design.clone(), dest: None };
                    let _ = world.hv.apply(&op, &design_footprint, &mut world.noc);
                    programmed.insert(*vi);
                    Some(ChurnEvent::Op(op))
                }
                _ => None, // Grow / Wire / Release: no elasticity
            },
            ChurnEvent::Request { vi, vr: _, payload } => match static_vr.get(vi) {
                Some(&vr) if programmed.contains(vi) => {
                    Some(ChurnEvent::Request { vi: *vi, vr, payload: Arc::clone(payload) })
                }
                _ => Some(event.clone()), // turned away: will be refused
            },
        })
        .collect()
}

struct RunStats {
    served: u64,
    refused: u64,
    mean_util: f64,
    rps: f64,
}

/// Replay one aligned trace against a fresh sharded engine, sampling
/// useful utilization at every request instant of the demand trace.
/// "Useful" = programmed regions whose owner is still active in the
/// *demand* world (a stranded region of a departed tenant counts as
/// waste, which is exactly the cost of a static allocation).
fn run_world(aligned: &[Option<ChurnEvent>], demand: &[ChurnEvent]) -> RunStats {
    let engine = ShardedEngine::start(|| System::empty("artifacts")).unwrap();
    let handle = engine.handle();
    let mut world = shadow(); // mirrors THIS run's tenancy
    let mut dem = shadow(); // mirrors demand (who is still active)
    let mut served = 0u64;
    let mut refused = 0u64;
    let mut util_sum = 0.0f64;
    let mut samples = 0u64;
    let t0 = Instant::now();
    for (i, demand_event) in demand.iter().enumerate() {
        if let ChurnEvent::Op(op) = demand_event {
            let _ = dem.hv.apply(op, &design_footprint, &mut dem.noc);
        }
        match &aligned[i] {
            None => {}
            Some(ChurnEvent::Op(op)) => {
                // Mirror into the shadow only what the engine accepted:
                // the engine's window-aware precheck refuses some ops
                // (release/grow against a still-reconfiguring region)
                // that a bare hypervisor would apply, and utilization
                // must be sampled from the engine's actual tenancy.
                if handle.lifecycle(op.clone()).is_ok() {
                    let _ = world.hv.apply(op, &design_footprint, &mut world.noc);
                }
            }
            Some(ChurnEvent::Request { vi, vr, payload }) => {
                match handle.call(*vi, *vr, Arc::clone(payload)) {
                    Ok(_) => served += 1,
                    Err(_) => refused += 1,
                }
                let active: HashSet<u16> = dem
                    .hv
                    .vis
                    .iter()
                    .filter(|(_, rec)| !rec.vrs.is_empty())
                    .map(|(&vi, _)| vi)
                    .collect();
                let useful = world
                    .hv
                    .vrs
                    .iter()
                    .filter(|r| {
                        matches!(&r.status, VrStatus::Programmed { vi, .. } if active.contains(vi))
                    })
                    .count();
                util_sum += useful as f64 / world.hv.vrs.len() as f64;
                samples += 1;
            }
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    engine.stop();
    RunStats {
        served,
        refused,
        mean_util: if samples > 0 { util_sum / samples as f64 } else { 0.0 },
        rps: served as f64 / secs.max(1e-9),
    }
}

fn main() {
    let smoke = smoke_mode();
    header(
        "Elastic tenant churn vs static allocation — live VR lifecycle",
        "elasticity (§III-A): run-time allocate/grow/release keeps regions busy — the 6x-utilization headline's mechanism",
    );
    let events_n = if smoke { 500 } else { 2000 };
    let cfg = ChurnConfig { seed: 0xC11A05, events: events_n, foreign_probe: 0.0 };
    let events = churn::generate(&cfg);
    let requests_total =
        events.iter().filter(|e| matches!(e, ChurnEvent::Request { .. })).count() as u64;
    // Remap request payloads onto the workload layer's seeded
    // heavy-tailed pool *before* deriving the static baseline, so both
    // worlds replay byte-identical demand.
    let pool = payload_pool(cfg.seed, requests_total as usize, &PayloadDist::heavy_tailed());
    let mut next_payload = 0usize;
    let events: Vec<ChurnEvent> = events
        .into_iter()
        .map(|e| match e {
            ChurnEvent::Request { vi, vr, .. } => {
                let payload = Arc::clone(&pool[next_payload]);
                next_payload += 1;
                ChurnEvent::Request { vi, vr, payload }
            }
            op => op,
        })
        .collect();
    let elastic_aligned: Vec<Option<ChurnEvent>> = events.iter().cloned().map(Some).collect();
    let static_aligned = static_baseline(&events);

    println!(
        "trace: {} events ({} requests, {} lifecycle ops), seed {:#x}\n",
        events.len(),
        requests_total,
        events.len() as u64 - requests_total,
        cfg.seed
    );

    let elastic = run_world(&elastic_aligned, &events);
    let stat = run_world(&static_aligned, &events);

    println!(
        "elastic: util {:>5.1}%  served {:>6} ({:>5} refused)  {:>8.0} req/s",
        elastic.mean_util * 100.0,
        elastic.served,
        elastic.refused,
        elastic.rps
    );
    println!(
        "static : util {:>5.1}%  served {:>6} ({:>5} refused)  {:>8.0} req/s",
        stat.mean_util * 100.0,
        stat.served,
        stat.refused,
        stat.rps
    );
    if stat.mean_util > 0.0 {
        println!(
            "-> elasticity gain: {:.2}x utilization, {:.2}x requests served\n",
            elastic.mean_util / stat.mean_util,
            elastic.served as f64 / stat.served.max(1) as f64
        );
    }

    check(
        "every request got exactly one reply in both runs",
        elastic.served + elastic.refused == requests_total
            && stat.served + stat.refused == requests_total,
    );
    check(
        "elastic mean utilization exceeds the static allocation",
        elastic.mean_util > stat.mean_util,
    );
    check("elastic serves more requests than the static allocation", elastic.served > stat.served);
    check("static run turns tenants away (the stranding cost is real)", stat.refused > 0);

    // Smoke runs persist too — CI uploads BENCH_*.json as artifacts, and
    // the embedded "smoke" flag lets trajectory tooling filter them.
    let json = format!(
        "{{\n  \"bench\": \"elastic_churn\",\n  \"smoke\": {smoke},\n  \"events\": {},\n  \"requests\": {requests_total},\n  \"elastic_util\": {:.4},\n  \"static_util\": {:.4},\n  \"elastic_served\": {},\n  \"static_served\": {},\n  \"elastic_rps\": {:.1},\n  \"static_rps\": {:.1}\n}}\n",
        events.len(),
        elastic.mean_util,
        stat.mean_util,
        elastic.served,
        stat.served,
        elastic.rps,
        stat.rps
    );
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_churn.json");
    match std::fs::write(&out, &json) {
        Ok(()) => println!("wrote {}:\n{json}", out.display()),
        Err(e) => check(&format!("write {} ({e})", out.display()), false),
    }
    finish();
}
