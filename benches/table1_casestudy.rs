//! Table I + Fig 13: VR allocation, per-accelerator resource utilization,
//! and the case-study placement, with the §V-D1 utilization headlines.

use fpga_mt::accel::CASE_STUDY;
use fpga_mt::bench_support::{check, header};
use fpga_mt::device::{Device, Resources};
use fpga_mt::placer;
use fpga_mt::util::table::Table;

fn main() {
    header(
        "Table I / Fig 13 — case study: 6 accelerators from 5 VIs on one device",
        "NoC+apps ~1.71% of CLB area; VR5-sized jobs: ~5 on a 7-series, hundreds on a VU9P; 6x utilization",
    );
    let mut t = Table::new(vec!["accel (VR->VI)", "LUT", "LUTRAM", "FF", "DSP", "BRAM"]);
    for a in &CASE_STUDY {
        t.row(vec![
            format!("{} (VR{}->VI{})", a.display, a.vr + 1, a.vi),
            a.resources.lut.to_string(),
            a.resources.lutram.to_string(),
            a.resources.ff.to_string(),
            a.resources.dsp.to_string(),
            a.resources.bram.to_string(),
        ]);
    }
    t.print();

    let device = Device::vu9p();
    let (_, fp) = placer::case_study_floorplan(&device).unwrap();
    let labels: Vec<(usize, String)> =
        CASE_STUDY.iter().map(|a| (a.vr, format!("{} (VI{})", a.display, a.vi))).collect();
    println!("\n{}", placer::ascii::render(&device, &fp, &labels));

    // §V-D1 claims.
    let vr5 = fp.pblocks.get(fp.vr_pb[4]);
    check("VR pblock = 1121 CLBs = 8968 LUTs", vr5.rect.clbs() == 1121 && vr5.capacity().lut == 8968);
    check("NoC < 1% of chip", fp.noc_clb_fraction(&device) < 0.01);

    let total_used: Resources =
        CASE_STUDY.iter().fold(Resources::ZERO, |acc, a| acc + a.resources);
    let noc_luts = 2 * 305 + 491; // two 3-port + one 4-port router
    let frac = (total_used.lut + noc_luts) as f64 / device.capacity.lut as f64;
    println!("NoC + applications LUT share: {:.2}% (paper: 1.71% of CLB area)", frac * 100.0);
    check("NoC+apps ~1-2% of device", (0.005..0.025).contains(&frac));

    let vr5_job = Resources::new(8968, 0, 0, 0, 0);
    let on_small = Device::artix7_class().max_instances(&vr5_job);
    let on_vu9p = device.max_instances(&vr5_job);
    println!("VR5-sized instances: 7-series-class {on_small}, VU9P {on_vu9p}");
    check("7-series fits ~5", (3..=8).contains(&on_small));
    check("VU9P fits >100", on_vu9p > 100);
    check("6 workloads / 5 tenants on one device (6x utilization)", CASE_STUDY.len() == 6);
}
