//! Isolation under attack — the red-team bench.
//!
//! One seeded hostile trace (`coordinator::redteam`: six attack classes
//! layered on cooperative churn) replays through all three serving
//! backends — serial, sharded, and a single-device fleet. The bench
//! reports per-class attempt/refusal tallies, the enforcement-point
//! counters each backend accumulated, replay throughput, and the
//! worst-pair cross-tenant leakage proxy for the case-study floorplan.
//!
//! Checks (enforced in `--smoke` too, non-zero exit on failure):
//! - the canonical replay log is byte-identical on all three backends;
//! - every cooperative op applies; zero foreign bytes cross the boundary;
//! - every attack class is attempted, and every class except the ingress
//!   flood is refused outright (flood heads queue, tails backpressure);
//! - rejected / backpressured / denied-op counters all fire;
//! - the leakage proxy stays under its gated bound for every co-located
//!   pairing.

use fpga_mt::api::{SerialBackend, ServingBackend};
use fpga_mt::bench_support::{check, finish, header, smoke_mode};
use fpga_mt::coordinator::metrics::Metrics;
use fpga_mt::coordinator::redteam::{
    self, AttackClass, AttackSurface, RedteamConfig, RedteamEvent, RedteamReplay,
};
use fpga_mt::coordinator::{ShardedEngine, System};
use fpga_mt::estimate::{leakage_between, TenantActivity, LEAKAGE_BOUND};
use fpga_mt::fleet::{FleetCluster, FleetConfig};
use fpga_mt::noc::Topology;
use std::time::Instant;

struct Run {
    label: &'static str,
    replay: RedteamReplay,
    metrics: Metrics,
    events_per_sec: f64,
}

fn run_surface<B: ServingBackend + AttackSurface>(backend: B, trace: &[RedteamEvent]) -> Run {
    let label = backend.surface_label();
    let t0 = Instant::now();
    let replay = redteam::replay(&backend, trace);
    let secs = t0.elapsed().as_secs_f64();
    let metrics = backend.shutdown();
    Run { label, replay, metrics, events_per_sec: trace.len() as f64 / secs.max(1e-9) }
}

/// Worst cross-tenant leakage score over every ordered co-located
/// pairing of the case-study deployment (3 two-region tenants on one
/// physical column), at full victim duty.
fn worst_leakage() -> f64 {
    let topo = Topology::single_column(3);
    let holdings: [[usize; 2]; 3] = [[0, 1], [2, 3], [4, 5]];
    let mut worst = 0.0f64;
    for (ai, attacker) in holdings.iter().enumerate() {
        for (vi, victim) in holdings.iter().enumerate() {
            if ai != vi {
                let report = leakage_between(&topo, attacker, &TenantActivity::new(victim, 1.0));
                worst = worst.max(report.score);
            }
        }
    }
    worst
}

fn main() {
    let smoke = smoke_mode();
    header(
        "Isolation under attack — hostile trace replay on every backend",
        "tenancy boundary (§IV-C): access monitor, epoch tickets, ownership prechecks, and bounded ingress hold under adversarial churn",
    );
    let cfg = RedteamConfig {
        seed: 0xBAD_5EED,
        events: if smoke { 200 } else { 600 },
        attack_rate: 0.35,
    };
    let trace = redteam::generate(&cfg);
    let attacks =
        trace.iter().filter(|e| matches!(e, RedteamEvent::Attack { .. })).count();
    println!(
        "trace: {} events ({} attacks), seed {:#x}, attack rate {}\n",
        trace.len(),
        attacks,
        cfg.seed,
        cfg.attack_rate
    );

    let serial = run_surface(SerialBackend::new(System::empty("artifacts").unwrap()), &trace);
    let sharded = run_surface(ShardedEngine::start(|| System::empty("artifacts")).unwrap(), &trace);
    let fleet = run_surface(FleetCluster::start(FleetConfig::new(1)).unwrap(), &trace);
    let runs = [&serial, &sharded, &fleet];

    println!("{:<12} {:>10} {:>10} {:>12} {:>10} {:>12}", "backend", "rejected", "backpres.", "denied ops", "foreign B", "events/s");
    for run in runs {
        println!(
            "{:<12} {:>10} {:>10} {:>12} {:>10} {:>12.0}",
            run.label,
            run.metrics.rejected,
            run.metrics.backpressured,
            run.metrics.denied_ops,
            run.replay.foreign_bytes,
            run.events_per_sec
        );
    }
    println!();
    println!("{:<20} {:>10} {:>10}", "attack class", "attempts", "refused");
    for class in AttackClass::ALL {
        let tally = serial.replay.tally(class);
        println!("{:<20} {:>10} {:>10}", class.label(), tally.attempts, tally.refused);
    }
    let leak = worst_leakage();
    println!("\nworst co-located leakage score: {leak:.4} (bound {LEAKAGE_BOUND})\n");

    for run in runs {
        let label = run.label;
        check(
            &format!("{label}: every cooperative op applies"),
            run.replay.coop_op_failures == 0,
        );
        check(
            &format!("{label}: zero foreign bytes cross the tenancy boundary"),
            run.replay.foreign_bytes == 0,
        );
        check(
            &format!("{label}: every attack class attempted"),
            run.replay.all_classes_attempted(),
        );
        for class in AttackClass::ALL {
            let tally = run.replay.tally(class);
            if class == AttackClass::IngressFlood {
                check(
                    &format!("{label}: flood tails backpressured, heads queued"),
                    tally.refused > 0 && tally.attempts > tally.refused,
                );
            } else {
                check(
                    &format!("{label}: every {} attempt refused", class.label()),
                    tally.refused == tally.attempts,
                );
            }
        }
        check(
            &format!("{label}: all three enforcement counters fire"),
            run.metrics.rejected > 0
                && run.metrics.backpressured > 0
                && run.metrics.denied_ops > 0,
        );
    }
    for other in [&sharded, &fleet] {
        check(
            &format!("serial vs {}: replay logs byte-identical", other.label),
            serial.replay.log == other.replay.log,
        );
        check(
            &format!("serial vs {}: tallies and counters identical", other.label),
            serial.replay.tallies == other.replay.tallies
                && serial.metrics.rejected == other.metrics.rejected
                && serial.metrics.backpressured == other.metrics.backpressured
                && serial.metrics.denied_ops == other.metrics.denied_ops,
        );
    }
    check("leakage proxy under bound for every co-located pairing", leak < LEAKAGE_BOUND);

    let mut per_class = String::new();
    for class in AttackClass::ALL {
        let tally = serial.replay.tally(class);
        per_class.push_str(&format!(
            "  \"{}\": {{ \"attempts\": {}, \"refused\": {} }},\n",
            class.label(),
            tally.attempts,
            tally.refused
        ));
    }
    let json = format!(
        "{{\n  \"bench\": \"isolation\",\n  \"smoke\": {smoke},\n  \"events\": {},\n  \"attacks\": {attacks},\n{per_class}  \"attacks_refused\": {},\n  \"rejected\": {},\n  \"backpressured\": {},\n  \"denied_ops\": {},\n  \"foreign_bytes\": {},\n  \"leakage_worst\": {:.4},\n  \"leakage_bound\": {LEAKAGE_BOUND},\n  \"serial_events_per_sec\": {:.1},\n  \"sharded_events_per_sec\": {:.1},\n  \"fleet_events_per_sec\": {:.1}\n}}\n",
        trace.len(),
        serial.replay.total_refused(),
        serial.metrics.rejected,
        serial.metrics.backpressured,
        serial.metrics.denied_ops,
        serial.replay.foreign_bytes,
        leak,
        serial.events_per_sec,
        sharded.events_per_sec,
        fleet.events_per_sec
    );
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_isolation.json");
    match std::fs::write(&out, &json) {
        Ok(()) => println!("wrote {}:\n{json}", out.display()),
        Err(e) => check(&format!("write {} ({e})", out.display()), false),
    }
    finish();
}
