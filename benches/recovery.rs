//! Control-plane recovery — the event-sourcing gates and costs.
//!
//! 1. **Full recovery** — a journaled fleet is driven through a seeded
//!    control churn trace (admissions, growth, retirement, migration,
//!    device failure), then rebuilt from its journal alone. The gate is
//!    byte-identical state ([`ControlDigest`] equality); the cost — wall
//!    time to replay the full history — is the reported perf point.
//! 2. **Crash sweep** — the controller is killed at *every* entry
//!    boundary and recovered from that prefix; every boundary must
//!    rebuild the exact digest the live controller held there. The
//!    `recovered_ok` counter (one per verified boundary) is what CI's
//!    sed gate asserts is positive.
//! 3. **Compaction** — a snapshot journal synthesized from live state
//!    ([`compacted_log`]) must recover an equivalent *serving* state
//!    from fewer entries and bytes than the full history.
//! 4. **Persistence** — writes `BENCH_recovery.json` (smoke runs too,
//!    tagged, so CI uploads the trajectory as an artifact).
//!
//! [`ControlDigest`]: fpga_mt::control::ControlDigest

use fpga_mt::bench_support::{check, finish, header, smoke_mode};
use fpga_mt::control::{
    compacted_log, control_trace, decode_log, drive_control_trace, recover_scheduler, CrashPlan,
    LogStore, MemLog,
};
use fpga_mt::fleet::{FleetConfig, FleetScheduler, PlacePolicy};
use std::time::Instant;

/// Boot a 2-device journaled fleet (digest trace on) and drive a seeded
/// control churn trace through it.
fn churned_fleet(events: usize, seed: u64) -> (FleetScheduler, MemLog) {
    let mut sched = FleetScheduler::start(FleetConfig {
        policy: PlacePolicy::Spread,
        ..FleetConfig::new(2)
    })
    .expect("fleet boots");
    let log = MemLog::new();
    sched.attach_journal(Box::new(log.clone()), true).expect("journal attaches");
    drive_control_trace(&mut sched, &control_trace(2, events, seed));
    (sched, log)
}

fn main() {
    let smoke = smoke_mode();
    header(
        "Control-plane recovery — event-sourced journal replay",
        "every mutation journaled; crash at any boundary, recover byte-identical state",
    );
    let events = if smoke { 16 } else { 48 };

    // ---- 1. full recovery: replay the whole history, gate on digests ----
    let (sched, log) = churned_fleet(events, 0x5EED_F1EE);
    let journal_bytes = log.snapshot().len();
    let (entries, _, damage) = decode_log(&log.snapshot());
    let journal_entries = entries.len();
    let t0 = Instant::now();
    let (recovered, report) =
        recover_scheduler(Box::new(log.clone())).expect("full journal recovers");
    let full_recovery_ms = t0.elapsed().as_secs_f64() * 1e3;
    println!(
        "journal: {journal_entries} entries, {journal_bytes} bytes after {events} churn events\n  full recovery: {} entries replayed in {full_recovery_ms:.1} ms",
        report.entries,
    );
    check("live journal is a clean prefix (no tail damage)", damage.is_none());
    check("full recovery replays every entry", report.entries == journal_entries);
    check(
        "recovered state is byte-identical to the live controller",
        recovered.control_digest() == sched.control_digest(),
    );

    // ---- 2. crash sweep: kill the controller at every boundary ----
    let t1 = Instant::now();
    let plan = CrashPlan::capture(&sched).expect("crash plan captures");
    let recovered_ok = plan.assert_all_boundaries().expect("every boundary recovers");
    let sweep_ms = t1.elapsed().as_secs_f64() * 1e3;
    println!(
        "  crash sweep: {recovered_ok}/{} boundaries recovered byte-identical in {sweep_ms:.1} ms",
        plan.len()
    );
    check("crash sweep covers every journal boundary", recovered_ok == plan.len());
    check("at least one boundary verified", recovered_ok > 0);

    // ---- 3. compaction: snapshot journal beats full history ----
    let compact = compacted_log(&sched, log.fence()).expect("compaction synthesizes");
    let compacted_bytes = compact.snapshot().len();
    let compacted_entries = decode_log(&compact.snapshot()).0.len();
    let (from_compact, _) =
        recover_scheduler(Box::new(compact)).expect("compacted journal recovers");
    println!(
        "  compaction: {journal_entries} entries / {journal_bytes} B -> {compacted_entries} entries / {compacted_bytes} B"
    );
    check(
        "compacted journal is no larger than the full history",
        compacted_entries <= journal_entries && compacted_bytes <= journal_bytes,
    );
    check(
        "compacted recovery serves the same state (serving digest equality)",
        from_compact.serving_digest() == sched.serving_digest(),
    );
    let _ = from_compact.stop();
    let _ = recovered.stop();
    let _ = sched.stop();

    // ---- 4. persist the perf point (smoke runs too: CI uploads it) ----
    let json = format!(
        "{{\n  \"bench\": \"recovery\",\n  \"smoke\": {smoke},\n  \"churn_events\": {events},\n  \"journal_entries\": {journal_entries},\n  \"journal_bytes\": {journal_bytes},\n  \"recovered_ok\": {recovered_ok},\n  \"crash_points\": {},\n  \"compacted_entries\": {compacted_entries},\n  \"compacted_bytes\": {compacted_bytes},\n  \"full_recovery_ms\": {full_recovery_ms:.2},\n  \"sweep_ms\": {sweep_ms:.2}\n}}\n",
        plan.len(),
    );
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_recovery.json");
    match std::fs::write(&out, &json) {
        Ok(()) => println!("\nwrote {}:\n{json}", out.display()),
        Err(e) => check(&format!("write {} ({e})", out.display()), false),
    }
    finish();
}
