//! Fig 9: router power (logic / signal / clock / BRAM) across configs.

use fpga_mt::bench_support::{check, header};
use fpga_mt::estimate::{router_power_mw, RouterConfig};
use fpga_mt::util::table::{fnum, Table};

fn main() {
    header(
        "Fig 9 — power consumption",
        "4-port bufferless up to 2.7x of 3-port; buffered up to 3.11x of bufferless (led by logic)",
    );
    let mut t = Table::new(vec!["config", "width", "logic", "signal", "clock", "bram", "total mW"]);
    for &buffered in &[false, true] {
        for ports in [3u32, 4] {
            for w in [32u32, 64, 128, 256] {
                let cfg = if buffered {
                    RouterConfig::buffered(ports, w)
                } else {
                    RouterConfig::bufferless(ports, w)
                };
                let p = router_power_mw(&cfg);
                t.row(vec![
                    format!("{}p {}", ports, if buffered { "buf" } else { "nobuf" }),
                    w.to_string(),
                    fnum(p.logic_mw),
                    fnum(p.signal_mw),
                    fnum(p.clock_mw),
                    fnum(p.bram_mw),
                    fnum(p.total_mw()),
                ]);
            }
        }
    }
    t.print();

    let mut max43: f64 = 0.0;
    let mut maxbuf: f64 = 0.0;
    for w in [32u32, 64, 128, 256] {
        let p3 = router_power_mw(&RouterConfig::bufferless(3, w)).total_mw();
        let p4 = router_power_mw(&RouterConfig::bufferless(4, w)).total_mw();
        max43 = max43.max(p4 / p3);
        for p in [3u32, 4] {
            let b = router_power_mw(&RouterConfig::buffered(p, w)).total_mw();
            let nb = router_power_mw(&RouterConfig::bufferless(p, w)).total_mw();
            maxbuf = maxbuf.max(b / nb);
        }
    }
    println!("\nmax 4-port/3-port ratio: {max43:.2} (paper: up to 2.7x)");
    println!("max buffered/bufferless ratio: {maxbuf:.2} (paper: up to 3.11x)");
    check("4p/3p ratio in (1.5, 2.75]", max43 > 1.5 && max43 <= 2.75);
    check("buffered ratio in (2.0, 3.2]", maxbuf > 2.0 && maxbuf <= 3.2);
}
