//! Ablation benches for the design choices DESIGN.md calls out:
//! - reduced-radix (3/4-port) vs a hypothetical 5-port mesh router cost;
//! - bufferless vs buffered area/power/Fmax across the sweep;
//! - direct VR-VR links vs routed path for the elastic streaming hop;
//! - fold-relay cost of the double-column flavor.

use fpga_mt::bench_support::{check, header};
use fpga_mt::estimate::{router_fmax_mhz, router_power_mw, router_resources, RouterConfig};
use fpga_mt::device::Device;
use fpga_mt::noc::{NocSim, Payload, Topology};
use fpga_mt::util::table::{fnum, Table};

fn main() {
    header(
        "Ablations — NoC design choices",
        "quantify each §IV decision: radix reduction, bufferless, direct links, column folding",
    );

    // (1) radix: extrapolate the structural model to 5 ports (mesh router).
    let dev = Device::vu9p();
    let mut t = Table::new(vec!["radix", "LUT(32b)", "FF(32b)", "mW(32b)", "Fmax MHz"]);
    for ports in [3u32, 4] {
        let cfg = RouterConfig::bufferless(ports, 32);
        let r = router_resources(&cfg);
        t.row(vec![
            format!("{ports}-port (ours)"),
            r.lut.to_string(),
            r.ff.to_string(),
            fnum(router_power_mw(&cfg).total_mw()),
            fnum(router_fmax_mhz(&cfg, &dev)),
        ]);
    }
    // 5-port mesh estimate: crossbar term m(n-1)w grows 20/12 = 1.67x over
    // 4-port; delay adds another arbitration level (~+25%).
    let r4 = router_resources(&RouterConfig::bufferless(4, 32));
    let lut5 = (r4.lut as f64 * 20.0 / 12.0) as u64;
    let ff5 = (r4.ff as f64 * 20.0 / 12.0) as u64;
    t.row(vec![
        "5-port (2D mesh, extrapolated)".to_string(),
        lut5.to_string(),
        ff5.to_string(),
        "-".to_string(),
        fnum(1.0e6 / (1000.0 * 1.25)),
    ]);
    t.print();
    check("radix reduction saves >30% vs mesh router", (r4.lut as f64) < lut5 as f64 * 0.7);

    // (2) direct link vs routed path for the FPU->AES stream.
    let mut routed = NocSim::new(Topology::single_column(3));
    for vr in 0..6 {
        routed.assign_vr(vr, 3);
    }
    let h = routed.header_for(3, 3);
    let n_flits = 256;
    for i in 0..n_flits {
        routed.send(2, h, vec![0u8; 4], i);
    }
    routed.drain(100_000);
    let routed_cycles = routed.cycle();

    let mut direct = NocSim::new(Topology::single_column(3));
    for vr in 0..6 {
        direct.assign_vr(vr, 3);
    }
    direct.wire_direct(2, 3).unwrap();
    let h = direct.header_for(3, 3);
    for i in 0..n_flits {
        direct.send_direct(2, h, vec![0u8; 4], i);
    }
    direct.drain(100_000);
    let direct_cycles = direct.cycle();
    println!(
        "\nstreaming {n_flits} flits FPU->AES: routed {routed_cycles} cycles, direct {direct_cycles} cycles"
    );
    check("direct link at least as fast as routed", direct_cycles <= routed_cycles);

    // (3) fold relay: same logical line, single vs double column.
    for (name, topo) in
        [("single-column 6", Topology::single_column(6)), ("double-column 6", Topology::double_column(6))]
    {
        let n = topo.n_vrs();
        let mut sim = NocSim::new(topo);
        for vr in 0..n {
            sim.assign_vr(vr, 1);
        }
        // End-to-end worst-case path: VR0 -> last VR.
        let h = sim.header_for(1, n - 1);
        sim.send(0, h, Payload::empty(), 0);
        sim.drain(10_000);
        println!("{name}: end-to-end latency {} cycles", sim.stats.latency.mean());
    }
    println!("(double-column pays +1 relay cycle at the fold for 2x the VRs per die height)");
}
