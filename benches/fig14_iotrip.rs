//! Fig 14: IO trip time per accelerator, multi-tenant vs directIO.

use fpga_mt::accel::CASE_STUDY;
use fpga_mt::bench_support::{bench, check, header};
use fpga_mt::cloud::{fig14_io_trips, IoConfig};
use fpga_mt::util::table::{fnum, Table};

fn main() {
    header(
        "Fig 14 — IO trip comparison",
        "no significant difference: e.g. AES 31 µs multi-tenant vs 29 µs single-tenant; penalty = a few µs",
    );
    let accels: Vec<(&str, u32)> =
        CASE_STUDY.iter().map(|a| (a.display, (a.vr / 2 + 1) as u32)).collect();
    let cfg = IoConfig::default();
    let rows = fig14_io_trips(&accels, 20_000, &cfg, 7);
    let mut t = Table::new(vec!["accelerator", "directIO µs", "multi-tenant µs", "penalty µs"]);
    for r in &rows {
        t.row(vec![
            r.accel.clone(),
            fnum(r.direct_us),
            fnum(r.multi_us),
            fnum(r.multi_us - r.direct_us),
        ]);
    }
    t.print();

    let all_close = rows.iter().all(|r| {
        (26.0..33.0).contains(&r.direct_us)
            && (28.0..36.0).contains(&r.multi_us)
            && r.multi_us - r.direct_us < 6.0
    });
    check("both schemes ~28-32 µs, penalty single-digit µs", all_close);
    let avg_penalty =
        rows.iter().map(|r| r.multi_us - r.direct_us).sum::<f64>() / rows.len() as f64;
    println!("\naverage multi-tenant penalty: {avg_penalty:.1} µs for 6x device utilization");

    bench("fig14 model: 6 accels x 20k trips", 1, 5, || {
        std::hint::black_box(fig14_io_trips(&accels, 20_000, &cfg, 7));
    });
}
