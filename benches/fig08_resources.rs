//! Fig 8: router resource utilization (registers, BRAM/LUTRAM, LUTs) for
//! 3-/4-port, buffered/bufferless routers, width 32..256.

use fpga_mt::bench_support::{bench, check, header};
use fpga_mt::estimate::{router_resources, RouterConfig};
use fpga_mt::util::table::Table;

fn main() {
    header(
        "Fig 8 — router resource utilization",
        "3-port saves ~40% FFs / ~50% LUTs vs 4-port; buffered adds LUT/FF + BRAM/LUTRAM",
    );
    let mut t = Table::new(vec!["config", "width", "LUT", "LUTRAM", "FF", "BRAM"]);
    for &buffered in &[false, true] {
        for ports in [3u32, 4] {
            for w in [32u32, 64, 128, 256] {
                let cfg = if buffered {
                    RouterConfig::buffered(ports, w)
                } else {
                    RouterConfig::bufferless(ports, w)
                };
                let r = router_resources(&cfg);
                t.row(vec![
                    format!("{}p {}", ports, if buffered { "buf" } else { "nobuf" }),
                    w.to_string(),
                    r.lut.to_string(),
                    r.lutram.to_string(),
                    r.ff.to_string(),
                    r.bram.to_string(),
                ]);
            }
        }
    }
    t.print();

    // Shape checks against the paper's claims.
    let l3 = router_resources(&RouterConfig::bufferless(3, 32));
    let l4 = router_resources(&RouterConfig::bufferless(4, 32));
    check("anchor: 3-port 32b = 305 LUTs", l3.lut == 305);
    check("anchor: 4-port 32b ~= 491 LUTs", (l4.lut as i64 - 491).abs() <= 1);
    let mut lut_ok = true;
    let mut ff_ok = true;
    for w in [32u32, 64, 128, 256] {
        let a = router_resources(&RouterConfig::bufferless(3, w));
        let b = router_resources(&RouterConfig::bufferless(4, w));
        lut_ok &= (0.35..=0.55).contains(&(1.0 - a.lut as f64 / b.lut as f64));
        ff_ok &= (0.3..=0.52).contains(&(1.0 - a.ff as f64 / b.ff as f64));
    }
    check("3-port saves ~50% LUTs across widths", lut_ok);
    check("3-port saves ~40% FFs across widths", ff_ok);

    bench("estimate::router_resources full sweep", 10, 100, || {
        for &b in &[false, true] {
            for p in [3u32, 4] {
                for w in [32u32, 64, 128, 256] {
                    let cfg = if b { RouterConfig::buffered(p, w) } else { RouterConfig::bufferless(p, w) };
                    std::hint::black_box(router_resources(&cfg));
                }
            }
        }
    });
}
