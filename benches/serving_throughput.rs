//! Serving-surface A/B: the serial reference backend vs the sharded
//! per-VR pipeline, and the pipelined batch path vs per-call submission
//! — all driven through the one `ServingBackend`/`Session` API.
//!
//! Four sections:
//! 1. **Equivalence** — replays one deterministic trace through sessions
//!    on the serial backend and the sharded engine and checks
//!    byte-identical responses (outputs, modeled timings, epochs) and
//!    identical merged metrics totals.
//! 2. **Throughput** — all 5 VIs drive their VRs concurrently (one
//!    closed-loop session per VI, fanned out with `runtime::SweepRunner`)
//!    for a fixed time window against each backend; reports aggregate
//!    requests/sec and the sharded-over-serial speedup. On a multi-core
//!    host the sharded engine must reach >= 2x.
//! 3. **Batch pipeline** — one tenant holding all six regions submits the
//!    same round-robin demand per-call (one round trip each) and via
//!    `Session::submit_batch` (whole arrival slices, one dispatcher
//!    wakeup each; the shards pipeline the compute). The batch path must
//!    beat per-call on closed-loop throughput — the win the new API's
//!    batched submission exists for.
//! 4. **NoC contention** — a streaming-heavy multi-column deployment
//!    (12 two-region fpu->aes tenants on `multi_column(12, 4)`, every
//!    request crossing the gated NoC section) runs once on the
//!    single-lock gate and once on the per-column partitioned gate
//!    (`ShardedEngine::start_with_gate`). Reports
//!    `partitioned_speedup`; non-smoke, the partitioned gate must win.
//! 5. **Persistence** — writes the numbers to `BENCH_serving.json` so the
//!    perf trajectory has data across PRs (including the `batches`
//!    counter and the `partitioned_speedup` the CI smoke gates assert).
//!
//! `cargo bench --bench serving_throughput [-- --smoke]`: smoke mode runs
//! CI-sized iteration counts and skips the host-dependent speedup gates
//! (CI runners may be 2-core), but still enforces every equivalence
//! check and that the batch path was exercised.

use fpga_mt::accel::CASE_STUDY;
use fpga_mt::api::{BatchItem, SerialBackend, ServingBackend, Session, TenancyBuilder, TenantRef};
use fpga_mt::bench_support::{check, finish, header, smoke_mode};
use fpga_mt::coordinator::{GateMode, ShardedEngine, System};
use fpga_mt::noc::Topology;
use fpga_mt::runtime::SweepRunner;
use fpga_mt::util::Rng;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Deterministic replay trace across all six shards (no rejections, so
/// every response can be compared field by field): `(vi, vr, payload)`.
fn replay_trace(n: usize, seed: u64) -> Vec<(u16, usize, Arc<[u8]>)> {
    let mut rng = Rng::new(seed);
    let specs: Vec<(u16, usize)> = CASE_STUDY.iter().map(|s| (s.vi, s.vr)).collect();
    (0..n)
        .map(|_| {
            let (vi, vr) = specs[rng.index(specs.len())];
            let len = 32 + rng.index(224);
            let payload: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
            (vi, vr, Arc::from(payload))
        })
        .collect()
}

/// One session per case-study VI, plus a `vr -> (session index, region)`
/// resolver for trace replay through the session surface.
fn case_study_sessions<B: ServingBackend>(backend: &B) -> Vec<Session> {
    (1..=5u16).map(|vi| backend.session(TenantRef::Vi(vi)).expect("case-study VI")).collect()
}

fn replay_via_sessions<B: ServingBackend>(
    backend: &B,
    trace: &[(u16, usize, Arc<[u8]>)],
) -> Vec<fpga_mt::coordinator::Response> {
    let sessions = case_study_sessions(backend);
    trace
        .iter()
        .map(|(vi, vr, p)| {
            let session = &sessions[(*vi - 1) as usize];
            let region = session.region_of_vr(*vr).expect("case-study region");
            session.submit(region, Arc::clone(p)).expect("trace request serves")
        })
        .collect()
}

fn equivalence_section(trace_len: usize) -> bool {
    let t = replay_trace(trace_len, 0x5EED);

    let serial = SerialBackend::new(System::case_study("artifacts").unwrap());
    let serial_resps = replay_via_sessions(&serial, &t);
    let sm = serial.shutdown();

    let sharded = ShardedEngine::start(|| System::case_study("artifacts")).unwrap();
    let sharded_resps = replay_via_sessions(&sharded, &t);
    let shm = sharded.shutdown();

    let responses_identical = serial_resps.iter().zip(&sharded_resps).all(|(a, b)| {
        a.path == b.path
            && a.epoch == b.epoch
            && a.outputs.len() == b.outputs.len()
            && a.outputs.iter().zip(&b.outputs).all(|(x, y)| x.shape == y.shape && x.data == y.data)
            && a.timing.io_us == b.timing.io_us
            && a.timing.noc_cycles == b.timing.noc_cycles
    });
    check(
        "responses byte-identical (outputs, path, modeled timing, epoch)",
        responses_identical,
    );
    check("merged requests equal serial", sm.requests == shm.requests);
    check("merged rejected equal serial", sm.rejected == shm.rejected);
    check(
        "merged byte counters equal serial",
        sm.bytes_in == shm.bytes_in && sm.bytes_out == shm.bytes_out,
    );
    check(
        "merged io_us distribution matches serial",
        sm.io_us.count() == shm.io_us.count() && (sm.io_us.mean() - shm.io_us.mean()).abs() < 1e-9,
    );
    responses_identical
        && sm.requests == shm.requests
        && sm.bytes_in == shm.bytes_in
        && sm.bytes_out == shm.bytes_out
}

/// Closed-loop clients (one session per VI, fanned out on `SweepRunner`)
/// hammer one backend for `secs`; returns total requests completed. Both
/// backends hand over the same `(Session, region)` pairs, so the drive
/// loop is shared and the A/B fair by construction.
fn drive_closed_loop(clients: Vec<(Session, usize)>, secs: f64) -> u64 {
    let payload: Arc<[u8]> = (0..=255u8).collect::<Vec<u8>>().into();
    let deadline = Instant::now() + Duration::from_secs_f64(secs);
    SweepRunner::new(clients.len())
        .run(clients, |(session, region)| {
            let mut n = 0u64;
            while Instant::now() < deadline {
                session.submit(region, Arc::clone(&payload)).unwrap();
                n += 1;
            }
            n
        })
        .into_iter()
        .sum()
}

/// `(Session, region)` closed-loop clients — one VR per VI; VI3 drives
/// its FPU chain so streaming is in the mix.
fn throughput_clients<B: ServingBackend>(backend: &B) -> Vec<(Session, usize)> {
    CASE_STUDY
        .iter()
        .filter(|s| s.name != "aes")
        .map(|s| {
            let session = backend.session(TenantRef::Vi(s.vi)).expect("case-study VI");
            let region = session.region_of_vr(s.vr).expect("case-study region");
            (session, region)
        })
        .collect()
}

struct BatchRun {
    percall_rps: f64,
    batch_rps: f64,
    batches: u64,
}

/// One tenant holding all six regions (deployed through the
/// `TenancyBuilder` path): submit `total` round-robin requests per-call,
/// then the same demand as `slice`-sized batch slices, on a fresh engine
/// each so the comparison is clean.
fn batch_section(total: usize, slice: usize) -> BatchRun {
    let deploy = |engine: &ShardedEngine| {
        let plan = TenancyBuilder::new("wide")
            .region("huffman")
            .region("fft")
            .region("fpu")
            .region("aes")
            .region("canny")
            .region("fir")
            .plan()
            .unwrap();
        let tenant = engine.deploy(&plan).unwrap();
        engine.advance_clock(60_000.0).unwrap();
        engine.session(tenant).unwrap()
    };
    let payload: Arc<[u8]> = (0..=255u8).collect::<Vec<u8>>().into();

    let engine = ShardedEngine::start(|| System::empty("artifacts")).unwrap();
    let session = deploy(&engine);
    let regions = session.targets().len();
    let t0 = Instant::now();
    for i in 0..total {
        session.submit(i % regions, Arc::clone(&payload)).unwrap();
    }
    let percall_rps = total as f64 / t0.elapsed().as_secs_f64();
    engine.shutdown();

    let engine = ShardedEngine::start(|| System::empty("artifacts")).unwrap();
    let session = deploy(&engine);
    let t0 = Instant::now();
    let mut done = 0usize;
    while done < total {
        let n = slice.min(total - done);
        let batch: Vec<BatchItem> =
            (0..n).map(|i| BatchItem::new((done + i) % regions, Arc::clone(&payload))).collect();
        for result in session.submit_batch(&batch).unwrap() {
            result.unwrap();
        }
        done += n;
    }
    let batch_rps = total as f64 / t0.elapsed().as_secs_f64();
    let metrics = engine.shutdown();
    check("batch run conserves every request", metrics.requests == total as u64);
    BatchRun { percall_rps, batch_rps, batches: metrics.batches }
}

/// Streaming-heavy contention drive over the NoC gate: 12 two-region
/// `fpu -> aes` tenants on a 4-column device (adjacent-first allocation
/// lands 3 tenants per column), every request streaming its result
/// across the wired direct link inside the gated NoC section. The same
/// deployment and closed-loop drive run once per [`GateMode`]; only the
/// gate differs, so the ratio isolates the lock structure.
fn contention_rps(mode: GateMode, secs: f64) -> f64 {
    let engine = ShardedEngine::start_with_gate(
        || System::empty_on(Topology::multi_column(12, 4), "artifacts"),
        mode,
    )
    .unwrap();
    let tenants: Vec<TenantRef> = (0..12)
        .map(|t| {
            let plan = TenancyBuilder::new(&format!("stream{t}"))
                .region("fpu")
                .region("aes")
                .stream(0, 1)
                .plan()
                .unwrap();
            let tenant = engine.deploy(&plan).unwrap();
            engine.advance_clock(60_000.0).unwrap();
            tenant
        })
        .collect();
    let clients = || -> Vec<(Session, usize)> {
        tenants.iter().map(|&t| (engine.session(t).unwrap(), 0usize)).collect()
    };
    drive_closed_loop(clients(), secs * 0.2);
    let t0 = Instant::now();
    let served = drive_closed_loop(clients(), secs);
    let rps = served as f64 / t0.elapsed().as_secs_f64();
    let metrics = engine.shutdown();
    check(
        "contention run loses no request",
        metrics.rejected == 0 && metrics.requests >= served,
    );
    rps
}

fn main() {
    let smoke = smoke_mode();
    header(
        "Serving throughput — one surface: serial vs sharded, per-call vs batched",
        "space-sharing: independent VRs serve independent tenants concurrently; the batched session path pipelines one tenant across its shards",
    );
    let (trace_len, window_secs) = if smoke { (36, 0.25) } else { (120, 1.5) };

    // ---- 1. A/B equivalence on a replayed trace (session surface) ----
    let equivalent = equivalence_section(trace_len);

    // ---- 2. concurrent throughput, all 5 VIs at once ----
    let serial = SerialBackend::new(System::case_study("artifacts").unwrap());
    drive_closed_loop(throughput_clients(&serial), window_secs * 0.2);
    let t0 = Instant::now();
    let serial_requests = drive_closed_loop(throughput_clients(&serial), window_secs);
    let serial_rps = serial_requests as f64 / t0.elapsed().as_secs_f64();
    let serial_metrics = serial.shutdown();

    let sharded = ShardedEngine::start(|| System::case_study("artifacts")).unwrap();
    drive_closed_loop(throughput_clients(&sharded), window_secs * 0.2);
    let t0 = Instant::now();
    let sharded_requests = drive_closed_loop(throughput_clients(&sharded), window_secs);
    let sharded_rps = sharded_requests as f64 / t0.elapsed().as_secs_f64();
    let sharded_metrics = sharded.shutdown();

    let speedup = sharded_rps / serial_rps;
    println!(
        "\nconcurrent serving, 5 VIs closed-loop for {window_secs:.2}s per backend:\n  serial   {serial_rps:>10.0} req/s ({serial_requests} served)\n  sharded  {sharded_rps:>10.0} req/s ({sharded_requests} served)\n  speedup  {speedup:>10.2}x",
    );
    // Tail latency of the sharded run (merged per-shard sketches; the
    // sketch is order-independent, so these match a serial recording of
    // the same requests exactly).
    let (p50, p95, p99) = (
        sharded_metrics.latency_percentile(50.0),
        sharded_metrics.latency_percentile(95.0),
        sharded_metrics.latency_percentile(99.0),
    );
    println!("  sharded latency: p50 {p50:.0} µs, p95 {p95:.0} µs, p99 {p99:.0} µs");
    check("latency percentiles populated and ordered", p50 > 0.0 && p50 <= p95 && p95 <= p99);
    // Backend metrics also contain the warmup requests, hence `>=`.
    check(
        "no request lost or rejected under concurrent load",
        serial_metrics.requests >= serial_requests
            && sharded_metrics.requests >= sharded_requests
            && serial_metrics.rejected == 0
            && sharded_metrics.rejected == 0,
    );
    if smoke {
        println!("(smoke mode: >=2x speedup gate skipped; CI runners may be 2-core)");
    } else {
        check("sharded engine >= 2x serial requests/sec on this host", speedup >= 2.0);
    }

    // ---- 3. batched submission vs per-call, one wide tenant ----
    let (batch_total, batch_slice) = if smoke { (120, 24) } else { (720, 24) };
    let b = batch_section(batch_total, batch_slice);
    let batch_speedup = b.batch_rps / b.percall_rps;
    println!(
        "\nbatched session path, one tenant x 6 regions, {batch_total} requests:\n  per-call {:>10.0} req/s\n  batched  {:>10.0} req/s (slices of {batch_slice})\n  speedup  {batch_speedup:>10.2}x",
        b.percall_rps, b.batch_rps,
    );
    check("batch path exercised (batches counter > 0)", b.batches > 0);
    if smoke {
        println!("(smoke mode: batch>per-call gate skipped; CI runners may be 1-core)");
    } else {
        check(
            "submit_batch beats per-call submit on closed-loop throughput",
            batch_speedup > 1.0,
        );
    }

    // ---- 4. NoC contention: single lock vs per-column partitioned ----
    let contention_secs = window_secs * 0.5;
    let single_lock_rps = contention_rps(GateMode::SingleLock, contention_secs);
    let partitioned_rps = contention_rps(GateMode::Partitioned, contention_secs);
    let partitioned_speedup = partitioned_rps / single_lock_rps;
    println!(
        "\nNoC gate contention, 12 streaming tenants across 4 columns for {contention_secs:.2}s per gate:\n  single-lock  {single_lock_rps:>10.0} req/s\n  partitioned  {partitioned_rps:>10.0} req/s\n  speedup      {partitioned_speedup:>10.2}x",
    );
    if smoke {
        println!("(smoke mode: partitioning gate skipped; CI runners may be core-limited)");
    } else {
        check(
            "per-column partitioned gate beats the single lock on streaming load",
            partitioned_speedup > 1.0,
        );
    }

    // ---- 5. persist the perf point ----
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let json = format!(
        "{{\n  \"bench\": \"serving_throughput\",\n  \"smoke\": {smoke},\n  \"host_cores\": {cores},\n  \"vis\": 5,\n  \"window_secs\": {window_secs},\n  \"serial_rps\": {serial_rps:.1},\n  \"sharded_rps\": {sharded_rps:.1},\n  \"speedup\": {speedup:.3},\n  \"percall_rps\": {:.1},\n  \"batch_rps\": {:.1},\n  \"batch_speedup\": {batch_speedup:.3},\n  \"batches\": {},\n  \"single_lock_rps\": {single_lock_rps:.1},\n  \"partitioned_rps\": {partitioned_rps:.1},\n  \"partitioned_speedup\": {partitioned_speedup:.3},\n  \"p50_us\": {p50:.1},\n  \"p95_us\": {p95:.1},\n  \"p99_us\": {p99:.1},\n  \"equivalent\": {equivalent}\n}}\n",
        b.percall_rps, b.batch_rps, b.batches,
    );
    // `cargo bench` runs with cwd = the package dir (rust/); anchor the
    // output at the workspace root, where README/DESIGN document it.
    // Smoke runs write too — CI uploads BENCH_*.json as artifacts, and
    // the embedded "smoke" flag lets trajectory tooling filter them.
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_serving.json");
    match std::fs::write(&out, &json) {
        Ok(()) => println!("\nwrote {}:\n{json}", out.display()),
        Err(e) => check(&format!("write {} ({e})", out.display()), false),
    }

    finish();
}
