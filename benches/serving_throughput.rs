//! Serving-engine A/B: the serial single-executor engine vs the sharded
//! per-VR pipeline (the paper's space-sharing claim, measured in software).
//!
//! Three sections:
//! 1. **Equivalence** — replays one deterministic trace through both
//!    engines and checks byte-identical responses, identical modeled
//!    timings, and identical merged metrics totals.
//! 2. **Throughput** — all 5 VIs drive their VRs concurrently (one
//!    closed-loop client thread per VI, fanned out with
//!    `runtime::SweepRunner`) for a fixed time window against each engine;
//!    reports aggregate requests/sec and the sharded-over-serial speedup.
//!    This is the paper's utilization story: on the serial engine a fast
//!    tenant queues behind every slow tenant's compute; on the sharded
//!    engine each VR serves at its own pace. On a multi-core host the
//!    sharded engine must reach >= 2x.
//! 3. **Persistence** — writes the numbers to `BENCH_serving.json` so the
//!    perf trajectory has data across PRs.
//!
//! `cargo bench --bench serving_throughput [-- --smoke]`: smoke mode runs
//! CI-sized iteration counts and skips the speedup gate (CI runners may be
//! 2-core), but still enforces every equivalence check.

use fpga_mt::accel::CASE_STUDY;
use fpga_mt::bench_support::{check, finish, header, smoke_mode};
use fpga_mt::coordinator::server::Engine;
use fpga_mt::coordinator::{Response, ShardedEngine, System};
use fpga_mt::runtime::SweepRunner;
use fpga_mt::util::Rng;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Deterministic replay trace across all six shards (no rejections, so
/// every response can be compared field by field).
fn replay_trace(n: usize, seed: u64) -> Vec<(u16, usize, Arc<[u8]>)> {
    let mut rng = Rng::new(seed);
    let specs: Vec<(u16, usize)> = CASE_STUDY.iter().map(|s| (s.vi, s.vr)).collect();
    (0..n)
        .map(|_| {
            let (vi, vr) = specs[rng.index(specs.len())];
            let len = 32 + rng.index(224);
            let payload: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
            (vi, vr, Arc::from(payload))
        })
        .collect()
}

fn equivalence_section(trace_len: usize) -> bool {
    let t = replay_trace(trace_len, 0x5EED);

    let serial = Engine::start(|| System::case_study("artifacts")).unwrap();
    let sh = serial.handle();
    let serial_resps: Vec<_> =
        t.iter().map(|(vi, vr, p)| sh.call(*vi, *vr, Arc::clone(p)).unwrap()).collect();
    let sm = serial.stop();

    let sharded = ShardedEngine::start(|| System::case_study("artifacts")).unwrap();
    let h = sharded.handle();
    let sharded_resps: Vec<_> =
        t.iter().map(|(vi, vr, p)| h.call(*vi, *vr, Arc::clone(p)).unwrap()).collect();
    let shm = sharded.stop();

    let responses_identical = serial_resps.iter().zip(&sharded_resps).all(|(a, b)| {
        a.path == b.path
            && a.outputs.len() == b.outputs.len()
            && a.outputs.iter().zip(&b.outputs).all(|(x, y)| x.shape == y.shape && x.data == y.data)
            && a.timing.io_us == b.timing.io_us
            && a.timing.noc_cycles == b.timing.noc_cycles
    });
    check("responses byte-identical (outputs, path, modeled timing)", responses_identical);
    check("merged requests equal serial", sm.requests == shm.requests);
    check("merged rejected equal serial", sm.rejected == shm.rejected);
    check(
        "merged byte counters equal serial",
        sm.bytes_in == shm.bytes_in && sm.bytes_out == shm.bytes_out,
    );
    check(
        "merged io_us distribution matches serial",
        sm.io_us.count() == shm.io_us.count() && (sm.io_us.mean() - shm.io_us.mean()).abs() < 1e-9,
    );
    responses_identical
        && sm.requests == shm.requests
        && sm.bytes_in == shm.bytes_in
        && sm.bytes_out == shm.bytes_out
}

/// Closed-loop clients (one handle per VI, fanned out on `SweepRunner`)
/// hammer one engine for `secs`; returns total requests completed. The
/// engines' handle types differ, so the caller supplies the handles and
/// the call shim — the drive loop itself is shared, keeping the A/B fair
/// by construction.
fn drive_closed_loop<H: Send>(
    handles: Vec<(H, u16, usize)>,
    call: impl Fn(&H, u16, usize, Arc<[u8]>) -> anyhow::Result<Response> + Sync,
    secs: f64,
) -> u64 {
    let payload: Arc<[u8]> = (0..=255u8).collect::<Vec<u8>>().into();
    let deadline = Instant::now() + Duration::from_secs_f64(secs);
    SweepRunner::new(handles.len())
        .run(handles, |(h, vi, vr)| {
            let mut n = 0u64;
            while Instant::now() < deadline {
                call(&h, vi, vr, Arc::clone(&payload)).unwrap();
                n += 1;
            }
            n
        })
        .into_iter()
        .sum()
}

fn main() {
    let smoke = smoke_mode();
    header(
        "Serving throughput — serial executor vs sharded per-VR pipeline",
        "space-sharing: independent VRs serve independent tenants concurrently (6x utilization at single-tenant-comparable QoS)",
    );
    let (trace_len, window_secs) = if smoke { (36, 0.25) } else { (120, 1.5) };

    // ---- 1. A/B equivalence on a replayed trace ----
    let equivalent = equivalence_section(trace_len);

    // ---- 2. concurrent throughput, all 5 VIs at once ----
    // One VR per VI; VI3 drives its FPU chain so streaming is in the mix.
    let clients: Vec<(u16, usize)> =
        CASE_STUDY.iter().filter(|s| s.name != "aes").map(|s| (s.vi, s.vr)).collect();

    let serial = Engine::start(|| System::case_study("artifacts")).unwrap();
    let serial_handles = || clients.iter().map(|&(vi, vr)| (serial.handle(), vi, vr)).collect();
    drive_closed_loop(serial_handles(), |h, vi, vr, p| h.call(vi, vr, p), window_secs * 0.2);
    let t0 = Instant::now();
    let serial_requests =
        drive_closed_loop(serial_handles(), |h, vi, vr, p| h.call(vi, vr, p), window_secs);
    let serial_rps = serial_requests as f64 / t0.elapsed().as_secs_f64();
    let serial_metrics = serial.stop();

    let sharded = ShardedEngine::start(|| System::case_study("artifacts")).unwrap();
    let sharded_handles =
        || clients.iter().map(|&(vi, vr)| (sharded.handle(), vi, vr)).collect();
    drive_closed_loop(sharded_handles(), |h, vi, vr, p| h.call(vi, vr, p), window_secs * 0.2);
    let t0 = Instant::now();
    let sharded_requests =
        drive_closed_loop(sharded_handles(), |h, vi, vr, p| h.call(vi, vr, p), window_secs);
    let sharded_rps = sharded_requests as f64 / t0.elapsed().as_secs_f64();
    let sharded_metrics = sharded.stop();

    let speedup = sharded_rps / serial_rps;
    println!(
        "\nconcurrent serving, {} VIs closed-loop for {window_secs:.2}s per engine:\n  serial   {serial_rps:>10.0} req/s ({serial_requests} served)\n  sharded  {sharded_rps:>10.0} req/s ({sharded_requests} served)\n  speedup  {speedup:>10.2}x",
        clients.len(),
    );
    // Tail latency of the sharded run (merged per-shard sketches; the
    // sketch is order-independent, so these match a serial recording of
    // the same requests exactly).
    let (p50, p95, p99) = (
        sharded_metrics.latency_percentile(50.0),
        sharded_metrics.latency_percentile(95.0),
        sharded_metrics.latency_percentile(99.0),
    );
    println!("  sharded latency: p50 {p50:.0} µs, p95 {p95:.0} µs, p99 {p99:.0} µs");
    check("latency percentiles populated and ordered", p50 > 0.0 && p50 <= p95 && p95 <= p99);
    // Engine metrics also contain the warmup requests, hence `>=`.
    check(
        "no request lost or rejected under concurrent load",
        serial_metrics.requests >= serial_requests
            && sharded_metrics.requests >= sharded_requests
            && serial_metrics.rejected == 0
            && sharded_metrics.rejected == 0,
    );
    if smoke {
        println!("(smoke mode: >=2x speedup gate skipped; CI runners may be 2-core)");
    } else {
        check("sharded engine >= 2x serial requests/sec on this host", speedup >= 2.0);
    }

    // ---- 3. persist the perf point ----
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let json = format!(
        "{{\n  \"bench\": \"serving_throughput\",\n  \"smoke\": {smoke},\n  \"host_cores\": {cores},\n  \"vis\": {},\n  \"window_secs\": {window_secs},\n  \"serial_rps\": {serial_rps:.1},\n  \"sharded_rps\": {sharded_rps:.1},\n  \"speedup\": {speedup:.3},\n  \"p50_us\": {p50:.1},\n  \"p95_us\": {p95:.1},\n  \"p99_us\": {p99:.1},\n  \"equivalent\": {equivalent}\n}}\n",
        clients.len(),
    );
    // `cargo bench` runs with cwd = the package dir (rust/); anchor the
    // output at the workspace root, where README/DESIGN document it.
    // Smoke runs write too — CI uploads BENCH_*.json as artifacts, and
    // the embedded "smoke" flag lets trajectory tooling filter them.
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_serving.json");
    match std::fs::write(&out, &json) {
        Ok(()) => println!("\nwrote {}:\n{json}", out.display()),
        Err(e) => check(&format!("write {} ({e})", out.display()), false),
    }

    finish();
}
