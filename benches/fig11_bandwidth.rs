//! Fig 11: bandwidth per wire and per LUT vs CONNECT / Hoplite / LinkBlaze.

use fpga_mt::bench_support::{check, header};
use fpga_mt::device::Device;
use fpga_mt::estimate::{bw_per_lut_mbps, bw_per_wire_mbps, link_bandwidth_gbps, RouterConfig, BASELINES};
use fpga_mt::util::table::{fnum, Table};

fn main() {
    header(
        "Fig 11 — bandwidth comparison (32-bit routers)",
        "bw/wire: 6.3x CONNECT, 2.57x Hoplite & LB-Flex, 1.65x LB-Fast; bw/LUT: Hoplite & LB-Fast win",
    );
    let dev = Device::vu9p();
    let mut t = Table::new(vec!["design", "bw/wire Mb/s", "bw/LUT Mb/s"]);
    for ports in [3u32, 4] {
        let cfg = RouterConfig::bufferless(ports, 32);
        t.row(vec![
            format!("ours {ports}-port"),
            fnum(bw_per_wire_mbps(&cfg, &dev)),
            fnum(bw_per_lut_mbps(&cfg, &dev)),
        ]);
    }
    for b in BASELINES {
        t.row(vec![b.name.to_string(), fnum(b.bw_per_wire_mbps()), fnum(b.bw_per_lut_mbps())]);
    }
    t.print();

    let cfg = RouterConfig::bufferless(3, 32);
    let ours_w = bw_per_wire_mbps(&cfg, &dev);
    let ours_l = bw_per_lut_mbps(&cfg, &dev);
    let r = |name: &str| {
        BASELINES.iter().find(|b| b.name == name).unwrap()
    };
    check(
        "6.3x CONNECT bw/wire",
        (ours_w / r("CONNECT").bw_per_wire_mbps() - 6.3).abs() < 0.35,
    );
    check(
        "2.57x Hoplite bw/wire",
        (ours_w / r("Hoplite").bw_per_wire_mbps() - 2.57).abs() < 0.2,
    );
    check(
        "1.65x LinkBlaze Fast bw/wire",
        (ours_w / r("LinkBlaze Fast").bw_per_wire_mbps() - 1.65).abs() < 0.15,
    );
    check("Hoplite wins bw/LUT", r("Hoplite").bw_per_lut_mbps() > ours_l);
    check("LB-Fast wins bw/LUT", r("LinkBlaze Fast").bw_per_lut_mbps() > ours_l);
    println!(
        "\ndeployed NoC link bandwidth: {} Gbps (paper §V-D1: 25.6 Gbps)",
        link_bandwidth_gbps(32, 800.0)
    );
    check("25.6 Gbps headline", (link_bandwidth_gbps(32, 800.0) - 25.6).abs() < 1e-9);
}
