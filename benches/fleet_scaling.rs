//! Fleet scaling + migration conservation — the cluster-layer gates.
//!
//! 1. **Scaling** — closed demand round-robin over 6 tenants, with the
//!    per-device load held constant (weak scaling: N requests on 1
//!    device, 2N across 2). Throughput is measured on the *modeled*
//!    arrival clock (makespan = the slowest device's clock after the
//!    demand drains), so the gate is deterministic and host-independent:
//!    the 2-device fleet must reach **≥ 1.8x** the 1-device modeled
//!    throughput. Because devices share *no* state — separate
//!    hypervisors, floorplans, timing cores — the two makespans are
//!    identical and the ratio is exactly 2x; any cross-device coupling
//!    (a shared clock, a shared lock, unbalanced routing) would drag it
//!    below the gate. Wall-clock requests/sec is reported alongside for
//!    the perf trajectory, but not gated (CI runners may be 2-core).
//! 2. **Migration conservation** — client threads hammer a tenant while
//!    it live-migrates between devices: every submission gets exactly
//!    one reply (engine-side `Metrics::requests` equals the clients'
//!    `Ok` count — none lost, none duplicated), and post-migration
//!    requests land on the target device at the target's epoch.
//! 3. **Persistence** — writes `BENCH_fleet.json` (also in `--smoke`
//!    mode, tagged, so CI can upload the trajectory as an artifact),
//!    including the fleet-wide p50/p95/p99 latency percentiles.

use fpga_mt::bench_support::{check, finish, header, smoke_mode};
use fpga_mt::fleet::{FleetCluster, FleetConfig, PlacePolicy, TenantId};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

const DESIGNS: [&str; 6] = ["huffman", "fft", "fpu", "aes", "canny", "fir"];

struct ScalingRun {
    served: u64,
    makespan_us: f64,
    wall_rps: f64,
    p50: f64,
    p95: f64,
    p99: f64,
}

/// Drive `requests` round-robin over 6 single-region tenants spread
/// across `devices` devices; modeled throughput = served / makespan of
/// the slowest device's arrival clock.
fn scaling_run(devices: usize, requests: usize) -> ScalingRun {
    let fleet = FleetCluster::start(FleetConfig {
        policy: PlacePolicy::Spread,
        ..FleetConfig::new(devices)
    })
    .expect("fleet boots");
    let tenants: Vec<TenantId> = (0..6)
        .map(|i| fleet.admit_tenant(&format!("tenant-{i}"), DESIGNS[i]).expect("admits"))
        .collect();
    let payload: Arc<[u8]> = vec![7u8; 64].into();
    let t0 = Instant::now();
    let mut served = 0u64;
    for i in 0..requests {
        if fleet.submit(tenants[i % tenants.len()], Arc::clone(&payload)).is_ok() {
            served += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let makespan_us = (0..devices)
        .map(|d| fleet.clock_us(d).expect("alive device"))
        .fold(0.0f64, f64::max);
    // Fleet-level percentiles (ingress included — zero here, the bench
    // uses local links, so these match the device-side distribution).
    let (p50, p95, p99) = (
        fleet.latency_percentile(50.0),
        fleet.latency_percentile(95.0),
        fleet.latency_percentile(99.0),
    );
    fleet.stop().expect("first stop");
    ScalingRun { served, makespan_us, wall_rps: served as f64 / wall.max(1e-9), p50, p95, p99 }
}

struct MigrationRun {
    ok_total: u64,
    err_total: u64,
    recorded: u64,
    post_device: usize,
    post_epoch_ok: bool,
    migrations: u64,
}

/// Hammer one tenant from `clients` threads while it migrates device
/// 0 → 1 and back; return the conservation ledger.
fn migration_run(clients: usize, rounds: usize) -> MigrationRun {
    let fleet = FleetCluster::start(FleetConfig {
        policy: PlacePolicy::BinPack,
        ..FleetConfig::new(2)
    })
    .expect("fleet boots");
    let tenant = fleet.admit_tenant("mover", "aes").expect("admits");
    fleet.advance_clocks(10_000.0).expect("clock advance");
    let stop = Arc::new(AtomicBool::new(false));
    let mut joins = Vec::new();
    for c in 0..clients {
        let h = fleet.handle();
        let stop = Arc::clone(&stop);
        joins.push(std::thread::spawn(move || {
            let payload: Arc<[u8]> = vec![c as u8 + 1; 64].into();
            let (mut ok, mut err) = (0u64, 0u64);
            while !stop.load(Ordering::Relaxed) {
                match h.submit(tenant, Arc::clone(&payload)) {
                    Ok(_) => ok += 1,
                    Err(_) => err += 1,
                }
            }
            (ok, err)
        }));
    }
    // Admin over &self while the clients keep serving — the shared
    // front-end needs no exclusive scheduler ownership for a migration.
    for round in 0..rounds {
        std::thread::sleep(std::time::Duration::from_millis(15));
        let (from, to) = if round % 2 == 0 { (0, 1) } else { (1, 0) };
        fleet.migrate_tenant(tenant, from, to).expect("live migration");
    }
    std::thread::sleep(std::time::Duration::from_millis(15));
    stop.store(true, Ordering::Relaxed);
    let (mut ok_total, mut err_total) = (0u64, 0u64);
    for j in joins {
        let (ok, err) = j.join().expect("client thread");
        ok_total += ok;
        err_total += err;
    }
    // One final request: it must execute on the last migration's target
    // at that replica's epoch.
    let replicas = fleet.replicas(tenant);
    let post = fleet.submit(tenant, vec![9u8; 64]).expect("post-migration request");
    let post_device = post.device;
    // Compare the ENGINE-side epoch (stamped by the serving shard from
    // its validated admission ticket) against the route table's view —
    // not the router's copy against itself.
    let post_epoch_ok = replicas.len() == 1
        && post.device == replicas[0].device
        && post.response.epoch == replicas[0].epoch;
    let migrations = fleet.migrations().expect("live fleet");
    let metrics = fleet.stop().expect("first stop");
    MigrationRun {
        ok_total,
        err_total,
        recorded: metrics.requests,
        post_device,
        post_epoch_ok,
        migrations,
    }
}

fn main() {
    let smoke = smoke_mode();
    header(
        "Fleet scaling + cross-device migration — the cluster layer",
        "one scheduler over N independent devices: placement, front-end routing, live migration (beyond the paper's single-FPGA scope)",
    );
    // Weak scaling: hold the per-device demand constant (N on 1 device,
    // 2N across 2) so the modeled gate is exact, not a race of random
    // sums.
    let per_device = if smoke { 300 } else { 900 };

    // ---- 1. modeled 1 -> 2 device scaling ----
    let one = scaling_run(1, per_device);
    let two = scaling_run(2, 2 * per_device);
    let tp1 = one.served as f64 / one.makespan_us.max(1e-9);
    let tp2 = two.served as f64 / two.makespan_us.max(1e-9);
    let scaling = tp2 / tp1.max(1e-12);
    println!(
        "modeled demand: {per_device} requests per device over 6 tenants\n  1 device : {} served, makespan {:>9.0} µs, {:.4} req/µs ({:>8.0} req/s wall)\n  2 devices: {} served, makespan {:>9.0} µs, {:.4} req/µs ({:>8.0} req/s wall)\n  modeled scaling {scaling:.2}x",
        one.served, one.makespan_us, tp1, one.wall_rps, two.served, two.makespan_us, tp2, two.wall_rps,
    );
    println!(
        "  latency percentiles (1 device): p50 {:.0} µs, p95 {:.0} µs, p99 {:.0} µs",
        one.p50, one.p95, one.p99
    );
    check("every modeled request served on both fleets", {
        one.served == per_device as u64 && two.served == 2 * per_device as u64
    });
    check("fleet throughput scales >= 1.8x from 1 -> 2 devices", scaling >= 1.8);
    check("latency percentiles are populated and ordered", {
        one.p50 > 0.0 && one.p50 <= one.p95 && one.p95 <= one.p99
    });

    // ---- 2. migration conservation under live load ----
    let rounds = if smoke { 2 } else { 4 };
    let m = migration_run(3, rounds);
    println!(
        "\nmigration: {} round trips under load — {} ok / {} err replies, {} recorded, post-migration device {}",
        m.migrations, m.ok_total, m.err_total, m.recorded, m.post_device,
    );
    check(
        "migration conserves replies (every Ok recorded exactly once, none duplicated)",
        m.recorded == m.ok_total + 1,
    );
    check("no client-visible errors across migrations (generation retry covers the flip)", {
        m.err_total == 0
    });
    check("post-migration requests execute on the target device's epoch", m.post_epoch_ok);
    check("every migration round completed", m.migrations == rounds as u64);

    // ---- 3. persist the perf point (smoke runs too: CI uploads it) ----
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let json = format!(
        "{{\n  \"bench\": \"fleet_scaling\",\n  \"smoke\": {smoke},\n  \"host_cores\": {cores},\n  \"requests_per_device\": {per_device},\n  \"one_device_makespan_us\": {:.1},\n  \"two_device_makespan_us\": {:.1},\n  \"modeled_scaling\": {scaling:.3},\n  \"one_device_wall_rps\": {:.1},\n  \"two_device_wall_rps\": {:.1},\n  \"p50_us\": {:.1},\n  \"p95_us\": {:.1},\n  \"p99_us\": {:.1},\n  \"migration_rounds\": {},\n  \"migration_ok\": {},\n  \"migration_err\": {},\n  \"conserved\": {}\n}}\n",
        one.makespan_us,
        two.makespan_us,
        one.wall_rps,
        two.wall_rps,
        one.p50,
        one.p95,
        one.p99,
        m.migrations,
        m.ok_total,
        m.err_total,
        m.recorded == m.ok_total + 1,
    );
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_fleet.json");
    match std::fs::write(&out, &json) {
        Ok(()) => println!("\nwrote {}:\n{json}", out.display()),
        Err(e) => check(&format!("write {} ({e})", out.display()), false),
    }
    finish();
}
