//! Table II: cloud-FPGA architecture comparison (capabilities + IO trip).

use fpga_mt::bench_support::{check, header};
use fpga_mt::cloud::compare::table2;
use fpga_mt::cloud::IoConfig;
use fpga_mt::util::table::{fnum, Table};

fn main() {
    header(
        "Table II — cloud FPGA architecture comparison",
        "ours: the only scheme with realloc + elasticity + on-chip com at ~30 µs (best tradeoff)",
    );
    let rows = table2(&IoConfig::default(), 3);
    let mut t = Table::new(vec!["scheme", "realloc", "elasticity", "on-chip", "IO trip µs"]);
    for r in &rows {
        t.row(vec![
            r.name.to_string(),
            if r.runtime_realloc { "Yes" } else { "No" }.to_string(),
            if r.hw_elasticity { "Yes" } else { "No" }.to_string(),
            if r.on_chip_com { "Yes" } else { "No" }.to_string(),
            r.io_trip_us.map(fnum).unwrap_or_else(|| "-".into()),
        ]);
    }
    t.print();

    let ours = rows.iter().find(|r| r.name == "Our Work").unwrap();
    check("ours has all three capabilities", ours.runtime_realloc && ours.hw_elasticity && ours.on_chip_com);
    check("ours ~30 µs", (28.0..34.0).contains(&ours.io_trip_us.unwrap()));
    check(
        "orders of magnitude under PR-manager schemes [28]/[29]",
        rows.iter()
            .filter(|r| r.name.contains("[28]") || r.name.contains("[29]"))
            .all(|r| r.io_trip_us.unwrap() / ours.io_trip_us.unwrap() > 100.0),
    );
}
