//! Fig 15: streaming throughput, VIs colocated with the FPGA host (a) and
//! remote over Ethernet (b), payloads 100-400 KB — plus a "space-shared
//! serving" series measured on the real engines: aggregate ingress when
//! all 5 VIs stream through the serial executor vs the sharded per-VR
//! pipeline (see `benches/serving_throughput.rs` for the full A/B).

use fpga_mt::accel::CASE_STUDY;
use fpga_mt::api::{SerialBackend, ServingBackend, Session, TenantRef};
use fpga_mt::bench_support::{check, header};
use fpga_mt::cloud::{IoConfig, Link, Scheme};
use fpga_mt::coordinator::{ShardedEngine, System};
use fpga_mt::runtime::SweepRunner;
use fpga_mt::util::table::{fnum, Table};
use std::sync::Arc;
use std::time::Instant;

/// Aggregate ingress Gb/s when every VI pushes `n_per_vi` payloads of
/// `bytes` through one backend. Both backends hand over the same
/// `(Session, region)` pairs, so the drive loop is shared and the
/// serial/sharded comparison fair by construction.
fn ingress_gbps(clients: Vec<(Session, usize)>, bytes: usize, n_per_vi: usize) -> f64 {
    let payload: Arc<[u8]> = vec![0xA5u8; bytes].into();
    let n_clients = clients.len();
    let t0 = Instant::now();
    SweepRunner::new(n_clients).run(clients, |(session, region)| {
        for _ in 0..n_per_vi {
            session.submit(region, Arc::clone(&payload)).unwrap();
        }
    });
    (bytes * n_per_vi * n_clients) as f64 * 8.0 / (t0.elapsed().as_secs_f64() * 1e9)
}

/// One `(Session, region)` client per VI (FPU excluded: VI3 uses its AES
/// VR), opened through the unified serving surface.
fn clients<B: ServingBackend>(backend: &B) -> Vec<(Session, usize)> {
    CASE_STUDY
        .iter()
        .filter(|s| s.name != "fpu")
        .map(|s| {
            let session = backend.session(TenantRef::Vi(s.vi)).expect("case-study VI");
            let region = session.region_of_vr(s.vr).expect("case-study region");
            (session, region)
        })
        .collect()
}

fn main() {
    header(
        "Fig 15 — throughput study",
        "local: up to ~7 Gb/s at 400 KB (2x the [27] baseline); remote: up to 3x lower (Ethernet-bound)",
    );
    let cfg = IoConfig::default();
    let mut t = Table::new(vec!["payload KB", "local Gb/s", "remote Gb/s", "loss x"]);
    let mut local400 = 0.0;
    let mut remote400 = 0.0;
    for kb in [100u64, 150, 200, 250, 300, 350, 400] {
        let bytes = kb * 1024;
        let l = cfg.stream_gbps(Scheme::MultiTenant, bytes, &Link::local());
        let r = cfg.stream_gbps(Scheme::MultiTenant, bytes, &Link::testbed_ethernet());
        if kb == 400 {
            local400 = l;
            remote400 = r;
        }
        t.row(vec![kb.to_string(), fnum(l), fnum(r), fnum(l / r)]);
    }
    t.print();

    check("local reaches ~7 Gb/s at 400 KB", (6.5..8.0).contains(&local400));
    check("remote loses up to ~3x", (2.2..4.2).contains(&(local400 / remote400)));
    check(
        "2x the sw<->hw throughput reported in [27] (~3.5 Gb/s)",
        local400 / 3.5 > 1.8 && local400 / 3.5 < 2.4,
    );
    println!(
        "\nnote: the paper quotes a 100 Mb/s Ethernet spec yet reports only ~3x loss from ~7 Gb/s;\n\
         we model the observed behaviour (~3 Gb/s effective link). See EXPERIMENTS.md."
    );

    // ---- space-shared serving series: engine-measured ingress ----
    println!("\nspace-shared serving (measured on the engines, 5 concurrent VIs):");
    let mut t = Table::new(vec!["payload KB", "serial Gb/s", "sharded Gb/s", "gain x"]);
    let n_per_vi = 12;
    let mut min_gain = f64::INFINITY;
    for kb in [64usize, 256] {
        let bytes = kb * 1024;
        let backend = SerialBackend::new(System::case_study("artifacts").unwrap());
        let serial = ingress_gbps(clients(&backend), bytes, n_per_vi);
        backend.shutdown();
        let engine = ShardedEngine::start(|| System::case_study("artifacts")).unwrap();
        let sharded = ingress_gbps(clients(&engine), bytes, n_per_vi);
        engine.shutdown();
        min_gain = min_gain.min(sharded / serial);
        t.row(vec![kb.to_string(), fnum(serial), fnum(sharded), fnum(sharded / serial)]);
    }
    t.print();
    // Wall-clock ratio: only meaningful when the 12 threads involved are
    // not oversubscribed (cf. the smoke-mode skip in serving_throughput).
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if cores >= 4 {
        check(
            "space-shared serving ingress >= serial serving ingress at every payload size",
            min_gain >= 1.0,
        );
    } else {
        println!("(host has {cores} cores; skipping the ingress-gain gate — timings are noise)");
    }
}
