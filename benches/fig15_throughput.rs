//! Fig 15: streaming throughput, VIs colocated with the FPGA host (a) and
//! remote over Ethernet (b), payloads 100-400 KB.

use fpga_mt::bench_support::{check, header};
use fpga_mt::cloud::{IoConfig, Link, Scheme};
use fpga_mt::util::table::{fnum, Table};

fn main() {
    header(
        "Fig 15 — throughput study",
        "local: up to ~7 Gb/s at 400 KB (2x the [27] baseline); remote: up to 3x lower (Ethernet-bound)",
    );
    let cfg = IoConfig::default();
    let mut t = Table::new(vec!["payload KB", "local Gb/s", "remote Gb/s", "loss x"]);
    let mut local400 = 0.0;
    let mut remote400 = 0.0;
    for kb in [100u64, 150, 200, 250, 300, 350, 400] {
        let bytes = kb * 1024;
        let l = cfg.stream_gbps(Scheme::MultiTenant, bytes, &Link::local());
        let r = cfg.stream_gbps(Scheme::MultiTenant, bytes, &Link::testbed_ethernet());
        if kb == 400 {
            local400 = l;
            remote400 = r;
        }
        t.row(vec![kb.to_string(), fnum(l), fnum(r), fnum(l / r)]);
    }
    t.print();

    check("local reaches ~7 Gb/s at 400 KB", (6.5..8.0).contains(&local400));
    check("remote loses up to ~3x", (2.2..4.2).contains(&(local400 / remote400)));
    check(
        "2x the sw<->hw throughput reported in [27] (~3.5 Gb/s)",
        local400 / 3.5 > 1.8 && local400 / 3.5 < 2.4,
    );
    println!(
        "\nnote: the paper quotes a 100 Mb/s Ethernet spec yet reports only ~3x loss from ~7 Gb/s;\n\
         we model the observed behaviour (~3 Gb/s effective link). See EXPERIMENTS.md."
    );
}
