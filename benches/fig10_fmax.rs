//! Fig 10: maximum frequency vs data width, ours vs buffered vs LinkBlaze
//! Fast/Flex (+ CONNECT/Hoplite published points).

use fpga_mt::bench_support::{check, header};
use fpga_mt::device::Device;
use fpga_mt::estimate::{router_fmax_mhz, RouterConfig, BASELINES};
use fpga_mt::util::table::{fnum, Table};

fn main() {
    header(
        "Fig 10 — router Fmax vs data width",
        "1.5 GHz (3-port) / 1.0 GHz (4-port) at 32b; ~1 GHz for 64-256b; ~2x the state of the art",
    );
    let dev = Device::vu9p();
    let mut t = Table::new(vec!["design", "32b", "64b", "128b", "256b"]);
    for ports in [3u32, 4] {
        for &buffered in &[false, true] {
            let cells: Vec<String> = [32u32, 64, 128, 256]
                .iter()
                .map(|&w| {
                    let cfg = if buffered {
                        RouterConfig::buffered(ports, w)
                    } else {
                        RouterConfig::bufferless(ports, w)
                    };
                    fnum(router_fmax_mhz(&cfg, &dev))
                })
                .collect();
            let mut row = vec![format!("{}p {}", ports, if buffered { "buf" } else { "nobuf" })];
            row.extend(cells);
            t.row(row);
        }
    }
    for b in BASELINES {
        let mut row = vec![b.name.to_string()];
        row.extend([32u32, 64, 128, 256].iter().map(|&w| fnum(b.fmax_at_width(w))));
        t.row(row);
    }
    t.print();

    let f3 = router_fmax_mhz(&RouterConfig::bufferless(3, 32), &dev);
    let f4 = router_fmax_mhz(&RouterConfig::bufferless(4, 32), &dev);
    check("3-port anchor ~1.5 GHz", (f3 - 1500.0).abs() < 10.0);
    check("4-port anchor ~1.0 GHz", (f4 - 1000.0).abs() < 10.0);
    check("~2x Hoplite (638 MHz)", f3 / 638.0 > 2.0);
    check(">4x CONNECT (313 MHz)", f3 / 313.0 > 4.0);
    let ok_band = [64u32, 128, 256].iter().all(|&w| {
        let f = router_fmax_mhz(&RouterConfig::bufferless(4, w), &dev);
        (750.0..1500.0).contains(&f)
    });
    check("'about 1 GHz' for 64-256b", ok_band);
}
