//! Tracing-overhead A/B and the per-tenant telemetry report.
//!
//! Three sections:
//! 1. **Overhead** — the same seeded session-surface replay drives the
//!    sharded engine twice: telemetry enabled (the default) and disabled
//!    (`FPGA_MT_TELEMETRY=off`, read at engine construction). Each mode
//!    runs several timed windows and keeps its best, so the comparison
//!    measures the instrumentation, not scheduler noise. Tracing must
//!    cost < 10% closed-loop throughput — the gate this bench exists
//!    for; the CI smoke step re-asserts the JSON field.
//! 2. **Registry** — the tracing-on run's `telemetry_snapshot()` must
//!    cover every case-study tenant (per-tenant p50/p95/p99 modeled
//!    latency from the registry sketches), render every serving-path
//!    phase in the span log, and export through both the
//!    Prometheus-style and JSON exporters; the tracing-off run must
//!    snapshot empty.
//! 3. **Persistence** — writes `BENCH_telemetry.json` (including
//!    `tracing_overhead_pct`, which CI gates) so the observability cost
//!    has a trajectory across PRs.
//!
//! `cargo bench --bench telemetry_overhead [-- --smoke]`: smoke mode
//! runs CI-sized windows; every telemetry-content check and the
//! overhead gate stay enforced.

use fpga_mt::accel::CASE_STUDY;
use fpga_mt::api::{ServingBackend, Session, TenantRef};
use fpga_mt::bench_support::{check, finish, header, smoke_mode};
use fpga_mt::coordinator::{ShardedEngine, System};
use fpga_mt::telemetry::TelemetrySnapshot;
use fpga_mt::util::Rng;
use std::sync::Arc;
use std::time::Instant;

/// Deterministic replay trace across all six case-study shards (no
/// rejections, every request serves): `(vi, vr, payload)`.
fn replay_trace(n: usize, seed: u64) -> Vec<(u16, usize, Arc<[u8]>)> {
    let mut rng = Rng::new(seed);
    let specs: Vec<(u16, usize)> = CASE_STUDY.iter().map(|s| (s.vi, s.vr)).collect();
    (0..n)
        .map(|_| {
            let (vi, vr) = specs[rng.index(specs.len())];
            let len = 32 + rng.index(224);
            let payload: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
            (vi, vr, Arc::from(payload))
        })
        .collect()
}

/// Replay the whole trace once through per-VI sessions; returns elapsed
/// seconds. Sessions are opened once by the caller so repeated windows
/// measure serving, not session setup.
fn timed_replay(sessions: &[Session], trace: &[(u16, usize, Arc<[u8]>)]) -> f64 {
    let t0 = Instant::now();
    for (vi, vr, p) in trace {
        let session = &sessions[(*vi - 1) as usize];
        let region = session.region_of_vr(*vr).expect("case-study region");
        session.submit(region, Arc::clone(p)).expect("trace request serves");
    }
    t0.elapsed().as_secs_f64()
}

/// Drive one engine: warmup window + `windows` timed windows, keeping
/// the best. Returns `(best_rps, telemetry snapshot, requests driven)`.
fn drive(
    engine: &ShardedEngine,
    trace: &[(u16, usize, Arc<[u8]>)],
    windows: usize,
) -> (f64, TelemetrySnapshot, u64) {
    let sessions: Vec<Session> =
        (1..=5u16).map(|vi| engine.session(TenantRef::Vi(vi)).expect("case-study VI")).collect();
    timed_replay(&sessions, trace); // warmup
    let mut best_secs = f64::INFINITY;
    for _ in 0..windows {
        best_secs = best_secs.min(timed_replay(&sessions, trace));
    }
    let snapshot = engine.telemetry_snapshot().expect("telemetry snapshot");
    (trace.len() as f64 / best_secs, snapshot, ((windows + 1) * trace.len()) as u64)
}

fn main() {
    let smoke = smoke_mode();
    header(
        "Telemetry overhead — request tracing on vs off on the sharded engine",
        "observability must not tax the serving path: spans + per-tenant registry cost < 10% closed-loop throughput",
    );
    let (n, windows) = if smoke { (400, 3) } else { (4000, 5) };
    let trace = replay_trace(n, 0x7E1E);

    // ---- 1a. tracing on (the default) ----
    let engine = ShardedEngine::start(|| System::case_study("artifacts")).unwrap();
    let (on_rps, snapshot, driven) = drive(&engine, &trace, windows);
    let on_metrics = engine.shutdown();

    // ---- 1b. tracing off, via the runtime switch inside the engine
    // builder (process-global env mutation is unsound with threads and
    // deprecated on newer toolchains; `set_enabled` flips the same
    // atomic the FPGA_MT_TELEMETRY knob initializes) ----
    let engine = ShardedEngine::start(|| {
        let sys = System::case_study("artifacts")?;
        sys.telemetry.set_enabled(false);
        Ok(sys)
    })
    .unwrap();
    let (off_rps, off_snapshot, _) = drive(&engine, &trace, windows);
    let off_metrics = engine.shutdown();

    let overhead_pct = ((off_rps - on_rps) / off_rps * 100.0).max(0.0);
    println!(
        "\nreplay of {n} requests x {windows} windows (best window kept):\n  tracing on   {on_rps:>10.0} req/s\n  tracing off  {off_rps:>10.0} req/s\n  overhead     {overhead_pct:>9.2}%",
    );
    check("tracing costs < 10% closed-loop throughput", overhead_pct < 10.0);
    check(
        "both modes served the identical demand",
        on_metrics.requests == off_metrics.requests && on_metrics.rejected == 0,
    );
    check("disabled telemetry snapshots empty", off_snapshot == TelemetrySnapshot::default());

    // ---- 2. registry content from the tracing-on run ----
    let covered = (1..=5u16).all(|vi| snapshot.tenants.contains_key(&vi));
    check("registry covers every case-study tenant (VIs 1-5)", covered);
    let served: u64 = snapshot.tenants.values().map(|t| t.served).sum();
    check("registry served total equals requests driven", served == driven);
    let log = snapshot.span_log();
    let phases_present = ["admit-wait", "io-trip", "compute", "noc-stream"]
        .iter()
        .all(|phase| log.contains(phase));
    check("span log renders every serving-path phase (streaming included)", phases_present);
    check(
        "exporters render the registry",
        snapshot.prometheus_lines().contains("fpga_mt_tenant_served")
            && snapshot.to_json().contains("\"tenants\""),
    );
    let mut tenant_rows = String::new();
    println!();
    for (vi, stats) in &snapshot.tenants {
        let (p50, p95, p99) = (
            stats.latency.percentile(50.0),
            stats.latency.percentile(95.0),
            stats.latency.percentile(99.0),
        );
        println!(
            "  tenant vi={vi}: served {:>6}, modeled latency p50 {p50:.1} µs, p95 {p95:.1} µs, p99 {p99:.1} µs",
            stats.served,
        );
        check(
            &format!("tenant {vi} percentiles populated and ordered"),
            p50 > 0.0 && p50 <= p95 && p95 <= p99,
        );
        if !tenant_rows.is_empty() {
            tenant_rows.push_str(",\n");
        }
        tenant_rows.push_str(&format!(
            "    \"{vi}\": {{ \"served\": {}, \"p50_us\": {p50:.1}, \"p95_us\": {p95:.1}, \"p99_us\": {p99:.1} }}",
            stats.served,
        ));
    }

    // ---- 3. persist the perf point ----
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let json = format!(
        "{{\n  \"bench\": \"telemetry_overhead\",\n  \"smoke\": {smoke},\n  \"host_cores\": {cores},\n  \"requests_per_window\": {n},\n  \"windows\": {windows},\n  \"tracing_on_rps\": {on_rps:.1},\n  \"tracing_off_rps\": {off_rps:.1},\n  \"tracing_overhead_pct\": {overhead_pct:.3},\n  \"tenants\": {{\n{tenant_rows}\n  }}\n}}\n",
    );
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_telemetry.json");
    match std::fs::write(&out, &json) {
        Ok(()) => println!("\nwrote {}:\n{json}", out.display()),
        Err(e) => check(&format!("write {} ({e})", out.display()), false),
    }

    finish();
}
