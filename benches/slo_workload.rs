//! Open-loop SLO serving: predictive vs reactive vs static elasticity
//! on the flash-crowd scenario, at equal device count.
//!
//! One seeded open-loop demand stream (`workload::arrivals`) is served
//! three times against the same fleet topology, varying only the
//! controller mode:
//!
//! 1. **Static** — the admit-time allocation is all the tenant ever
//!    gets. The spike overruns one replica's capacity and, because the
//!    driver is open-loop, the backlog (and the recorded p99) grows
//!    without bound while arrivals stay on schedule.
//! 2. **Reactive** — grows only after the observed window p99 has
//!    already broken the target: the reconfiguration window lands on
//!    top of an already-blown tail.
//! 3. **Predictive** — EWMA demand forecast grows during the spike's
//!    ramp, before saturation, so the tail never blows.
//!
//! Gated here (and re-asserted from the JSON by CI): the three modes
//! saw identical demand; arrivals stayed on schedule; the static run
//! misses the spiking tenant's p99 SLO while the predictive run meets
//! it; and predictive SLO attainment is at least reactive's. Writes
//! `BENCH_slo.json`.
//!
//! `cargo bench --bench slo_workload [-- --smoke]`.

use fpga_mt::bench_support::{check, finish, header, smoke_mode};
use fpga_mt::workload::scenario::{self, Scenario, ScenarioOutcome};
use fpga_mt::workload::{ControlMode, Decision};

const SEED: u64 = 0x510AD;

fn run_mode(sc: &Scenario, mode: ControlMode) -> ScenarioOutcome {
    let out = scenario::run(sc, mode, SEED).expect("scenario run");
    let spike = &out.report.tenants[0];
    println!(
        "{:<10}  spike p99 {:>10.1} µs (target {:>8.1})  avail {:.4}  attainment {:>3.0}%  grows {} (+{} refused)  shrinks {}  sheds {}",
        mode.label(),
        spike.observed_p99_us,
        spike.target.p99_us,
        spike.observed_availability,
        out.report.attainment() * 100.0,
        out.grows_ok,
        out.grows_refused,
        out.shrinks_ok,
        out.flows.iter().map(|f| f.shed).sum::<u64>(),
    );
    out
}

fn main() {
    let smoke = smoke_mode();
    header(
        "Open-loop SLOs — predictive vs reactive vs static elasticity on a flash crowd",
        "the paper's utilization claim is only credible if SLOs survive demand the backend cannot throttle",
    );
    let mut sc = Scenario::flash_crowd();
    if smoke {
        sc = sc.smoke();
    }
    println!(
        "scenario '{}': {} devices, horizon {:.0} ms, window {:.0} ms, seed {SEED:#x}\n",
        sc.name,
        sc.devices,
        sc.horizon_us / 1000.0,
        sc.window_us / 1000.0
    );

    let stat = run_mode(&sc, ControlMode::Static);
    let reactive = run_mode(&sc, ControlMode::Reactive);
    let predictive = run_mode(&sc, ControlMode::Predictive);

    // -- demand equivalence: open loop means the backend cannot shape
    //    the offered load, so all three modes saw the same arrivals.
    check(
        "identical seeded demand across all three modes",
        stat.arrivals_total == reactive.arrivals_total
            && stat.arrivals_total == predictive.arrivals_total
            && stat.arrivals_total > 0,
    );
    let horizon = sc.horizon_us;
    check(
        "arrivals stayed on schedule in every mode (open loop)",
        [&stat, &reactive, &predictive]
            .iter()
            .all(|o| o.flows[0].last_arrival_us > 0.9 * horizon),
    );

    // -- the headline A/B at equal device count.
    let spike_static = &stat.report.tenants[0];
    let spike_pred = &predictive.report.tenants[0];
    check(
        "static allocation misses the spiking tenant's p99 SLO",
        !spike_static.p99_met,
    );
    check(
        "predictive controller meets the p99 SLO static missed",
        spike_pred.p99_met,
    );
    check(
        "predictive attainment >= reactive attainment (equal devices)",
        predictive.report.attainment() >= reactive.report.attainment(),
    );
    check("static never grew (it is the fixed baseline)", stat.grows_ok == 0);
    check("predictive grew the spiking tenant", predictive.grows_ok > 0);
    // Predictive must have acted during the ramp — before the spike
    // held at full multiplier (start 25%, full from 35% of horizon).
    let first_grow = predictive
        .decisions
        .iter()
        .find(|(_, d)| matches!(d, Decision::Grow { .. }))
        .map(|(t, _)| *t)
        .unwrap_or(f64::INFINITY);
    check(
        "predictive's first grow landed before the spike's hold phase ended",
        first_grow <= 0.45 * horizon,
    );

    let json = format!(
        "{{\n  \"bench\": \"slo_workload\",\n  \"smoke\": {smoke},\n  \"scenario\": \"{}\",\n  \"devices\": {},\n  \"arrivals\": {},\n  \"slo_p99_us\": {:.1},\n  \"static_p99_us\": {:.1},\n  \"reactive_p99_us\": {:.1},\n  \"predictive_p99_us\": {:.1},\n  \"static_attainment\": {:.4},\n  \"reactive_attainment\": {:.4},\n  \"predictive_attainment\": {:.4},\n  \"predictive_grows\": {},\n  \"predictive_shed\": {},\n  \"first_grow_ms\": {:.1}\n}}\n",
        sc.name,
        sc.devices,
        predictive.arrivals_total,
        spike_pred.target.p99_us,
        spike_static.observed_p99_us,
        reactive.report.tenants[0].observed_p99_us,
        spike_pred.observed_p99_us,
        stat.report.attainment(),
        reactive.report.attainment(),
        predictive.report.attainment(),
        predictive.grows_ok,
        predictive.flows.iter().map(|f| f.shed).sum::<u64>(),
        first_grow / 1000.0,
    );
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_slo.json");
    match std::fs::write(&out, &json) {
        Ok(()) => println!("\nwrote {}:\n{json}", out.display()),
        Err(e) => check(&format!("write {} ({e})", out.display()), false),
    }
    finish();
}
