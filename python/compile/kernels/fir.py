"""FIR filter accelerator (Table I: "FIR — a commonly used filter in
signal processing").

Hardware adaptation: an RTL FIR is a systolic MAC chain; on TPU the same
computation is a sliding-window dot product that the VPU vectorizes. The
Pallas kernel unrolls the (static) tap loop so each tap becomes one fused
multiply-add over the whole signal vector held in VMEM.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fir_kernel(x_ref, h_ref, o_ref, *, taps: int, n: int):
    """o[i] = sum_k h[k] * x[i + taps - 1 - k]  (x is left-padded)."""
    x = x_ref[...]
    h = h_ref[...]
    acc = jnp.zeros((n,), jnp.float32)
    for k in range(taps):  # static unroll: one VPU FMA per tap
        window = jax.lax.dynamic_slice(x, (taps - 1 - k,), (n,))
        acc = acc + h[k] * window
    o_ref[...] = acc


def fir(x: jax.Array, h: jax.Array) -> jax.Array:
    """Causal FIR: y[i] = sum_k h[k] * x[i-k], zero prehistory.

    x: f32[n], h: f32[taps] -> f32[n].
    """
    n = x.shape[0]
    taps = h.shape[0]
    xp = jnp.pad(x, (taps - 1, 0))
    import functools

    kernel = functools.partial(_fir_kernel, taps=taps, n=n)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(xp, h)
