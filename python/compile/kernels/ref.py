"""Pure-numpy oracles for every kernel — the CORE correctness signal.

Each reference is implemented independently of its kernel (different
algorithm or library call) so agreement is meaningful:
- fir_ref: np.convolve;
- dft_ref: np.fft.fft;
- conv2d_ref: explicit python loops;
- fpu_ref: numpy elementwise;
- aes_ref: textbook list-based AES (no jnp, own key schedule);
- huffman_expand_ref: fancy indexing.
"""

import numpy as np


def fir_ref(x: np.ndarray, h: np.ndarray) -> np.ndarray:
    return np.convolve(x, h)[: x.shape[0]].astype(np.float32)


def dft_ref(x_re: np.ndarray, x_im: np.ndarray):
    X = np.fft.fft(x_re + 1j * x_im, axis=-1)
    return X.real.astype(np.float32), X.imag.astype(np.float32)


def conv2d_ref(img: np.ndarray, kernel: np.ndarray) -> np.ndarray:
    kh, kw = kernel.shape
    h, w = img.shape
    ph, pw = kh // 2, kw // 2
    padded = np.pad(img, ((ph, kh - 1 - ph), (pw, kw - 1 - pw)))
    out = np.zeros((h, w), dtype=np.float64)
    for y in range(h):
        for x in range(w):
            out[y, x] = float((padded[y : y + kh, x : x + kw] * kernel).sum())
    return out.astype(np.float32)


def canny_ref(img: np.ndarray) -> np.ndarray:
    from .canny import GAUSS5, SOBEL_X, SOBEL_Y

    blurred = conv2d_ref(img, GAUSS5)
    gx = conv2d_ref(blurred, SOBEL_X)
    gy = conv2d_ref(blurred, SOBEL_Y)
    return np.sqrt(gx * gx + gy * gy).astype(np.float32)


def fpu_ref(a: np.ndarray, b: np.ndarray, c: np.ndarray) -> np.ndarray:
    s = a + b
    d = a - b
    m = a * b
    q = m / (np.abs(c) + 1.0)
    r = np.sqrt(np.abs(s * d))
    return (q + r + c).astype(np.float32)


# ---------------------------------------------------------------- AES ----

_SBOX_HEX = (
    "637c777bf26b6fc53001672bfed7ab76"
    "ca82c97dfa5947f0add4a2af9ca472c0"
    "b7fd9326363ff7cc34a5e5f171d83115"
    "04c723c31896059a071280e2eb27b275"
    "09832c1a1b6e5aa0523bd6b329e32f84"
    "53d100ed20fcb15b6acbbe394a4c58cf"
    "d0efaafb434d338545f9027f503c9fa8"
    "51a3408f929d38f5bcb6da2110fff3d2"
    "cd0c13ec5f974417c4a77e3d645d1973"
    "60814fdc222a908846eeb814de5e0bdb"
    "e0323a0a4906245cc2d3ac629195e479"
    "e7c8376d8dd54ea96c56f4ea657aae08"
    "ba78252e1ca6b4c6e8dd741f4bbd8b8a"
    "703eb5664803f60e613557b986c11d9e"
    "e1f8981169d98e949b1e87e9ce5528df"
    "8ca1890dbfe6426841992d0fb054bb16"
)
_SBOX = [int(_SBOX_HEX[i : i + 2], 16) for i in range(0, 512, 2)]


def _xt(a: int) -> int:
    a <<= 1
    if a & 0x100:
        a ^= 0x11B
    return a & 0xFF


def _key_expand_ref(key: list) -> list:
    rcon = 1
    w = [key[4 * i : 4 * i + 4] for i in range(4)]
    for i in range(4, 44):
        t = list(w[i - 1])
        if i % 4 == 0:
            t = t[1:] + t[:1]
            t = [_SBOX[b] for b in t]
            t[0] ^= rcon
            rcon = _xt(rcon)
        w.append([a ^ b for a, b in zip(w[i - 4], t)])
    return [sum(w[4 * r : 4 * r + 4], []) for r in range(11)]


def aes_ref(blocks: np.ndarray, key16: np.ndarray) -> np.ndarray:
    """Textbook AES-128 ECB over uint8[b,16] blocks; key is 16 raw bytes
    (the reference runs its own key schedule)."""
    rks = _key_expand_ref([int(b) for b in key16])
    out = []
    for blk in blocks:
        s = [int(b) ^ rks[0][i] for i, b in enumerate(blk)]
        for rnd in range(1, 10):
            s = [_SBOX[b] for b in s]
            s = [s[(i % 4) + 4 * (((i // 4) + (i % 4)) % 4)] for i in range(16)]
            ns = []
            for c in range(4):
                a = s[4 * c : 4 * c + 4]
                ns += [
                    _xt(a[0]) ^ _xt(a[1]) ^ a[1] ^ a[2] ^ a[3],
                    a[0] ^ _xt(a[1]) ^ _xt(a[2]) ^ a[2] ^ a[3],
                    a[0] ^ a[1] ^ _xt(a[2]) ^ _xt(a[3]) ^ a[3],
                    _xt(a[0]) ^ a[0] ^ a[1] ^ a[2] ^ _xt(a[3]),
                ]
            s = [b ^ rks[rnd][i] for i, b in enumerate(ns)]
        s = [_SBOX[b] for b in s]
        s = [s[(i % 4) + 4 * (((i // 4) + (i % 4)) % 4)] for i in range(16)]
        s = [b ^ rks[10][i] for i, b in enumerate(s)]
        out.append(s)
    return np.array(out, dtype=np.uint8)


def huffman_expand_ref(symbols: np.ndarray, table: np.ndarray) -> np.ndarray:
    idx = np.clip(symbols.astype(np.int64), 0, table.shape[0] - 1)
    return table[idx].astype(np.float32)
