"""Layer-1 Pallas kernels (+ plain-jnp AES/Huffman stages).

Every kernel here is the compute hot-spot of one of the paper's six
case-study accelerators (Table I), authored for TPU idioms but lowered with
``interpret=True`` so the AOT HLO runs on the CPU PJRT client (see
DESIGN.md section on hardware adaptation). ``ref.py`` holds the pure-numpy
oracles.
"""

from . import aes, canny, fft, fir, fpu, huffman, ref  # noqa: F401
