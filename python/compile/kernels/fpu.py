"""FPU accelerator (Table I: "FPU — it implements a single precision
floating point unit").

The OpenCores FPU exposes add/sub/mul/div/sqrt over IEEE-754 single
precision. The streaming equivalent here is a vector micro-program
exercising all five operations per element — a VPU-shaped elementwise
Pallas kernel (no MXU involvement, the point is FLOP coverage, not
matmul).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fpu_kernel(a_ref, b_ref, c_ref, o_ref):
    a = a_ref[...]
    b = b_ref[...]
    c = c_ref[...]
    s = a + b                       # add
    d = a - b                       # sub
    m = a * b                       # mul
    q = m / (jnp.abs(c) + 1.0)      # div (guarded)
    r = jnp.sqrt(jnp.abs(s * d))    # sqrt(|a^2 - b^2|)
    o_ref[...] = q + r + c


def fpu(a: jax.Array, b: jax.Array, c: jax.Array) -> jax.Array:
    """f32[n] x 3 -> f32[n]: q + r + c as computed above."""
    n = a.shape[0]
    return pl.pallas_call(
        _fpu_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(a, b, c)
