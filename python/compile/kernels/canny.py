"""Canny-edge accelerator (Table I: "Canny Edge implements an edge
detection algorithm").

Hardware adaptation: RTL edge detectors use line buffers shifting the image
past 3x3/5x5 window logic. On TPU the window logic becomes unrolled
shifted-image FMAs over a VMEM-resident tile (one fused multiply-add per
tap), and the line buffer becomes the BlockSpec HBM->VMEM schedule. The
pipeline is the classic front half of Canny: Gaussian blur, Sobel
gradients, gradient magnitude (the paper's IP reports the magnitude map).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _conv_kernel(img_ref, o_ref, *, taps, kh: int, kw: int, h: int, w: int):
    """Static-unrolled 2-D convolution over a padded image in VMEM."""
    img = img_ref[...]
    acc = jnp.zeros((h, w), jnp.float32)
    for dy in range(kh):
        for dx in range(kw):
            c = taps[dy][dx]
            if c == 0.0:
                continue
            win = jax.lax.dynamic_slice(img, (dy, dx), (h, w))
            acc = acc + c * win
    o_ref[...] = acc


def conv2d_same(img: jax.Array, kernel: np.ndarray) -> jax.Array:
    """'same' 2-D correlation with zero padding; taps are static floats."""
    kh, kw = kernel.shape
    h, w = img.shape
    ph, pw = kh // 2, kw // 2
    padded = jnp.pad(img, ((ph, kh - 1 - ph), (pw, kw - 1 - pw)))
    taps = tuple(tuple(float(v) for v in row) for row in np.asarray(kernel))
    k = functools.partial(_conv_kernel, taps=taps, kh=kh, kw=kw, h=h, w=w)
    return pl.pallas_call(
        k,
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.float32),
        interpret=True,
    )(padded)


GAUSS5 = (
    np.array(
        [
            [2, 4, 5, 4, 2],
            [4, 9, 12, 9, 4],
            [5, 12, 15, 12, 5],
            [4, 9, 12, 9, 4],
            [2, 4, 5, 4, 2],
        ],
        dtype=np.float32,
    )
    / 159.0
)
SOBEL_X = np.array([[-1, 0, 1], [-2, 0, 2], [-1, 0, 1]], dtype=np.float32)
SOBEL_Y = np.array([[-1, -2, -1], [0, 0, 0], [1, 2, 1]], dtype=np.float32)


def canny_magnitude(img: jax.Array) -> jax.Array:
    """Gaussian blur -> Sobel -> gradient magnitude. f32[h,w] -> f32[h,w]."""
    blurred = conv2d_same(img, GAUSS5)
    gx = conv2d_same(blurred, SOBEL_X)
    gy = conv2d_same(blurred, SOBEL_Y)
    return jnp.sqrt(gx * gx + gy * gy)
