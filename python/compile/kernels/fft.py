"""FFT accelerator (Table I: "FFT — heavily used in signal processing").

Hardware adaptation: an RTL FFT is a butterfly pipeline; the TPU-idiomatic
equivalent for fixed small transform sizes is a DFT-by-matmul against
precomputed twiddle matrices, which maps straight onto the MXU systolic
array (bf16/f32 matmul), exactly the kind of rethinking DESIGN.md's
hardware-adaptation section calls for. The Pallas kernel is a classic
VMEM-tiled matmul with a grid over (M, N, K) blocks and accumulation in
the output tile.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref):
    """One (bm, bn) output tile accumulating over the K grid axis."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += a_ref[...] @ b_ref[...]


def matmul(a: jax.Array, b: jax.Array, *, bm: int = 8, bn: int = 128, bk: int = 128) -> jax.Array:
    """Tiled Pallas matmul: f32[m,k] @ f32[k,n] -> f32[m,n].

    Block sizes follow MXU-friendly multiples; dims must divide evenly
    (the AOT models use power-of-two shapes).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (a.shape, b.shape, (bm, bn, bk))
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


@functools.lru_cache(maxsize=8)
def _twiddles(n: int):
    """DFT matrix split into real/imag parts, transposed for x @ W^T."""
    j, k = np.meshgrid(np.arange(n), np.arange(n), indexing="ij")
    ang = -2.0 * np.pi * j * k / n
    w_re = np.cos(ang).astype(np.float32)
    w_im = np.sin(ang).astype(np.float32)
    # W is symmetric (W^T = W), but keep the transpose explicit for clarity.
    return jnp.asarray(w_re.T), jnp.asarray(w_im.T)


def dft(x_re: jax.Array, x_im: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Batched DFT: f32[b,n] x 2 -> (X_re, X_im), X = x @ W^T.

    X_re = x_re @ Wre - x_im @ (-Wim)... concretely:
    X = (x_re + i x_im) (W_re + i W_im) with W = exp(-2 pi i jk/n).
    """
    n = x_re.shape[-1]
    w_re, w_im = _twiddles(n)
    X_re = matmul(x_re, w_re) - matmul(x_im, w_im)
    X_im = matmul(x_re, w_im) + matmul(x_im, w_re)
    return X_re, X_im
