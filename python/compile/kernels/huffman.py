"""Huffman-decoder accelerator, tensor stage (Table I: "Huffman Decoder
that is typically used in streaming applications").

Substitution note (DESIGN.md): bit-serial variable-length decoding is
data-dependent control flow — hostile to XLA and to the MXU/VPU. The real
canonical decoder therefore lives on the Rust side (`accel::huffman`);
the tensor stage compiled here is the *symbol expansion*: decoded symbol
indices are mapped through the reconstruction table (gather) and scaled —
the part of a streaming decoder that is a tensor op and benefits from the
accelerator at all.
"""

import jax
import jax.numpy as jnp


def expand(symbols_f32: jax.Array, table_f32: jax.Array) -> jax.Array:
    """out[i] = table[symbols[i]]. symbols: f32[n] (integer-valued),
    table: f32[t].

    Implemented as a one-hot matmul rather than a gather: the xla 0.5.1
    HLO-text parser mis-parses `gather` (see DESIGN.md), and on TPU a
    [n,t] one-hot times [t] table is MXU work anyway.
    """
    t = table_f32.shape[0]
    idx = jnp.clip(symbols_f32.astype(jnp.int32), 0, t - 1)
    onehot = (idx[:, None] == jnp.arange(t, dtype=jnp.int32)[None, :]).astype(jnp.float32)
    return onehot @ table_f32
