"""Layer-2 model registry: each of the paper's six accelerators as a
jittable compute graph calling the Layer-1 kernels.

Shapes are fixed per artifact (PJRT executables are shape-specialized, as
the paper's bitstreams are region-specialized). `MODELS` maps an
accelerator name to (fn, example_specs); `aot.py` lowers each entry to
`artifacts/<name>.hlo.txt`.
"""

import jax
import jax.numpy as jnp

from .kernels import aes, canny, fft, fir, fpu, huffman

F32 = jnp.float32


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def model_fir(x, h):
    """FIR: signal f32[1024], taps f32[16] -> f32[1024]."""
    return (fir.fir(x, h),)


def model_fft(x_re, x_im):
    """DFT: f32[8,256] x 2 -> (X_re, X_im)."""
    return fft.dft(x_re, x_im)


def model_canny(img):
    """Edge magnitude: f32[128,128] -> f32[128,128]."""
    return (canny.canny_magnitude(img),)


def model_fpu(a, b, c):
    """FPU micro-program: f32[4096] x 3 -> f32[4096]."""
    return (fpu.fpu(a, b, c),)


def model_aes(blocks, round_keys):
    """AES-128 ECB: blocks f32[16,16] (byte-valued), rks f32[11,16]."""
    return (aes.aes128_encrypt(blocks, round_keys),)


def model_huffman(symbols, table):
    """Symbol expansion: f32[2048] indices + f32[256] table."""
    return (huffman.expand(symbols, table),)


MODELS = {
    "fir": (model_fir, (spec(1024), spec(16))),
    "fft": (model_fft, (spec(8, 256), spec(8, 256))),
    "canny": (model_canny, (spec(128, 128),)),
    "fpu": (model_fpu, (spec(4096), spec(4096), spec(4096))),
    "aes": (model_aes, (spec(16, 16), spec(11, 16))),
    "huffman": (model_huffman, (spec(2048), spec(256))),
}
