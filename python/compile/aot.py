"""AOT compiler: lower every Layer-2 model to HLO *text* artifacts.

HLO text (not `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the Rust `xla` crate binds) rejects; the text parser reassigns ids
and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  python -m compile.aot [--out-dir ../artifacts] [--only name]
Writes  <out-dir>/<name>.hlo.txt and <out-dir>/manifest.txt.
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from .model import MODELS


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(True)  # print_large_constants: the 0.5.1 text parser reads elided constants as zeros


def lower_model(name: str) -> str:
    fn, specs = MODELS[name]
    lowered = jax.jit(fn).lower(*specs)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--only", default=None, help="lower a single model")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    names = [args.only] if args.only else sorted(MODELS)
    manifest = []
    for name in names:
        text = lower_model(name)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        _, specs = MODELS[name]
        shapes = ";".join("x".join(map(str, s.shape)) or "scalar" for s in specs)
        manifest.append(f"{name} inputs={len(specs)} shapes={shapes}")
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")


if __name__ == "__main__":
    main()
