"""Layer-2 model shape checks + AOT lowering smoke tests."""

import numpy as np
import pytest

from compile.model import MODELS
from compile.aot import lower_model


@pytest.mark.parametrize("name", sorted(MODELS))
def test_model_executes_at_example_shapes(name):
    fn, specs = MODELS[name]
    rng = np.random.default_rng(1)
    args = [rng.random(s.shape, np.float32) for s in specs]
    if name == "aes":
        args = [np.floor(a * 255.0).astype(np.float32) for a in args]
    outs = fn(*args)
    assert isinstance(outs, tuple) and len(outs) >= 1
    for o in outs:
        assert np.all(np.isfinite(np.asarray(o)))


@pytest.mark.parametrize("name", sorted(MODELS))
def test_model_lowers_to_hlo_text(name):
    text = lower_model(name)
    assert "HloModule" in text
    # interpret=True must have erased all Mosaic/custom-call lowering.
    assert "mosaic" not in text.lower()
