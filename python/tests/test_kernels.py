"""Kernel-vs-oracle correctness: each Pallas/jnp kernel against its
independent numpy reference, fixed shapes + hypothesis sweeps."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import aes, canny, fft, fir, fpu, huffman, ref

RNG = np.random.default_rng(42)


def f32(*shape, scale=1.0):
    return (RNG.standard_normal(shape) * scale).astype(np.float32)


# ------------------------------------------------------------------ FIR --

def test_fir_matches_convolve():
    x, h = f32(1024), f32(16)
    got = np.asarray(fir.fir(x, h))
    np.testing.assert_allclose(got, ref.fir_ref(x, h), rtol=1e-5, atol=1e-5)


def test_fir_impulse_recovers_taps():
    h = f32(8)
    x = np.zeros(64, np.float32)
    x[0] = 1.0
    got = np.asarray(fir.fir(x, h))
    np.testing.assert_allclose(got[:8], h, rtol=1e-6)
    np.testing.assert_allclose(got[8:], 0.0, atol=1e-7)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(8, 256), taps=st.integers(1, 32), seed=st.integers(0, 2**31))
def test_fir_hypothesis_shapes(n, taps, seed):
    r = np.random.default_rng(seed)
    x = r.standard_normal(n).astype(np.float32)
    h = r.standard_normal(taps).astype(np.float32)
    got = np.asarray(fir.fir(x, h))
    np.testing.assert_allclose(got, ref.fir_ref(x, h), rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------ FFT --

def test_matmul_matches_numpy():
    a, b = f32(8, 256), f32(256, 256)
    got = np.asarray(fft.matmul(a, b))
    np.testing.assert_allclose(got, a @ b, rtol=1e-4, atol=1e-3)


def test_dft_matches_npfft():
    xr, xi = f32(8, 256), f32(8, 256)
    gr, gi = fft.dft(xr, xi)
    er, ei = ref.dft_ref(xr, xi)
    np.testing.assert_allclose(np.asarray(gr), er, rtol=1e-3, atol=1e-2)
    np.testing.assert_allclose(np.asarray(gi), ei, rtol=1e-3, atol=1e-2)


def test_dft_real_signal_symmetry():
    xr = f32(2, 64)
    xi = np.zeros_like(xr)
    gr, gi = fft.dft(xr, xi)
    gr, gi = np.asarray(gr), np.asarray(gi)
    # X[k] = conj(X[N-k]) for real signals.
    np.testing.assert_allclose(gr[:, 1:], gr[:, :0:-1], rtol=1e-3, atol=1e-2)
    np.testing.assert_allclose(gi[:, 1:], -gi[:, :0:-1], rtol=1e-3, atol=1e-2)


@settings(max_examples=10, deadline=None)
@given(
    m=st.sampled_from([1, 2, 4, 8]),
    n=st.sampled_from([16, 32, 64]),
    k=st.sampled_from([16, 64, 128]),
    seed=st.integers(0, 2**31),
)
def test_matmul_hypothesis_shapes(m, n, k, seed):
    r = np.random.default_rng(seed)
    a = r.standard_normal((m, k)).astype(np.float32)
    b = r.standard_normal((k, n)).astype(np.float32)
    got = np.asarray(fft.matmul(a, b))
    np.testing.assert_allclose(got, a @ b, rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------- Canny --

def test_conv2d_matches_loops():
    img = f32(32, 32)
    got = np.asarray(canny.conv2d_same(img, canny.SOBEL_X))
    np.testing.assert_allclose(got, ref.conv2d_ref(img, canny.SOBEL_X), rtol=1e-4, atol=1e-4)


def test_canny_magnitude_matches_ref():
    img = np.abs(f32(48, 48, scale=64.0))
    got = np.asarray(canny.canny_magnitude(img))
    np.testing.assert_allclose(got, ref.canny_ref(img), rtol=1e-3, atol=1e-2)


def test_canny_flat_image_has_no_edges():
    img = np.full((32, 32), 7.0, np.float32)
    got = np.asarray(canny.canny_magnitude(img))
    # Interior (away from zero-padding halo) must be edge-free.
    np.testing.assert_allclose(got[6:-6, 6:-6], 0.0, atol=1e-4)


def test_canny_step_edge_detected():
    img = np.zeros((32, 32), np.float32)
    img[:, 16:] = 100.0
    got = np.asarray(canny.canny_magnitude(img))
    assert got[16, 16] > 50.0          # strong response on the edge
    assert got[16, 4] < 1.0            # none in the flat region


@settings(max_examples=10, deadline=None)
@given(h=st.integers(8, 48), w=st.integers(8, 48), seed=st.integers(0, 2**31))
def test_conv2d_hypothesis_shapes(h, w, seed):
    r = np.random.default_rng(seed)
    img = r.standard_normal((h, w)).astype(np.float32)
    got = np.asarray(canny.conv2d_same(img, canny.GAUSS5))
    np.testing.assert_allclose(got, ref.conv2d_ref(img, canny.GAUSS5), rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------ FPU --

def test_fpu_matches_ref():
    a, b, c = f32(4096), f32(4096), f32(4096)
    got = np.asarray(fpu.fpu(a, b, c))
    np.testing.assert_allclose(got, ref.fpu_ref(a, b, c), rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 1024), seed=st.integers(0, 2**31), scale=st.sampled_from([0.1, 1.0, 100.0]))
def test_fpu_hypothesis(n, seed, scale):
    r = np.random.default_rng(seed)
    a = (r.standard_normal(n) * scale).astype(np.float32)
    b = (r.standard_normal(n) * scale).astype(np.float32)
    c = (r.standard_normal(n) * scale).astype(np.float32)
    got = np.asarray(fpu.fpu(a, b, c))
    np.testing.assert_allclose(got, ref.fpu_ref(a, b, c), rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------ AES --

FIPS_KEY = np.arange(16, dtype=np.uint8)
FIPS_PT = np.frombuffer(bytes.fromhex("00112233445566778899aabbccddeeff"), np.uint8)
FIPS_CT = bytes.fromhex("69c4e0d86a7b0430d8cdb78070b4c55a")


def test_aes_ref_fips_vector():
    ct = ref.aes_ref(FIPS_PT.reshape(1, 16), FIPS_KEY)
    assert bytes(ct[0].tolist()) == FIPS_CT


def test_aes_jnp_fips_vector():
    rks = aes.key_expand(FIPS_KEY)
    out = aes.aes128_encrypt(
        FIPS_PT.reshape(1, 16).astype(np.float32), rks.astype(np.float32)
    )
    assert bytes(np.asarray(out, np.uint8)[0].tolist()) == FIPS_CT


def test_aes_batch_matches_ref():
    blocks = RNG.integers(0, 256, (16, 16), dtype=np.uint8)
    key = RNG.integers(0, 256, 16, dtype=np.uint8)
    rks = aes.key_expand(key)
    got = np.asarray(
        aes.aes128_encrypt(blocks.astype(np.float32), rks.astype(np.float32)), np.uint8
    )
    np.testing.assert_array_equal(got, ref.aes_ref(blocks, key))


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31), b=st.integers(1, 8))
def test_aes_hypothesis(seed, b):
    r = np.random.default_rng(seed)
    blocks = r.integers(0, 256, (b, 16), dtype=np.uint8)
    key = r.integers(0, 256, 16, dtype=np.uint8)
    rks = aes.key_expand(key)
    got = np.asarray(
        aes.aes128_encrypt(blocks.astype(np.float32), rks.astype(np.float32)), np.uint8
    )
    np.testing.assert_array_equal(got, ref.aes_ref(blocks, key))


def test_aes_key_schedule_matches_ref():
    key = RNG.integers(0, 256, 16, dtype=np.uint8)
    ours = aes.key_expand(key)
    theirs = np.array(ref._key_expand_ref([int(x) for x in key]), dtype=np.uint8)
    np.testing.assert_array_equal(ours, theirs)


# -------------------------------------------------------------- Huffman --

def test_huffman_expand_matches_ref():
    sym = RNG.integers(0, 256, 2048).astype(np.float32)
    table = f32(256)
    got = np.asarray(huffman.expand(sym, table))
    np.testing.assert_allclose(got, ref.huffman_expand_ref(sym, table))


def test_huffman_expand_clips_out_of_range():
    table = np.arange(4, dtype=np.float32)
    sym = np.array([-3.0, 0.0, 3.0, 99.0], np.float32)
    got = np.asarray(huffman.expand(sym, table))
    np.testing.assert_allclose(got, [0.0, 0.0, 3.0, 3.0])
